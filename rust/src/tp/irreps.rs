//! The typed irrep layout every equivariant operation speaks.
//!
//! An [`Irreps`] is an ordered list of `mul x l` segments (e3nn's
//! `"32x0 + 16x1 + 8x2"` notation, minus parity — the Gaunt basis is
//! parity-even by construction).  It is the *contract* between modules:
//! a flat `&[f64]` feature is interpreted against an `Irreps`, and every
//! [`EquivariantOp`](crate::tp::op::EquivariantOp) declares its input and
//! output layouts through one.
//!
//! # Layout invariants
//!
//! * Segments are stored in declaration order; segment `s` starts at
//!   [`Irreps::offset`]`(s)` and holds `mul` *slots* of `2l+1`
//!   coefficients each (slot stride = `2l+1`): index of `(s, channel c,
//!   m)` is `offset(s) + c*(2l+1) + (l + m)`.  Within a segment the
//!   layout is **mul-major** (all of channel 0's block, then channel
//!   1's, ...).
//! * [`Irreps::single`]`(L)` — one channel of every degree `0..=L` — is
//!   byte-compatible with the crate's historical `(L+1)^2` feature
//!   layout ([`crate::lm_index`]), so all pre-`Irreps` plans consume
//!   exactly the `mul = 1` case.
//! * [`Irreps::spherical`]`(C, L)` — `C` channels of every degree — is
//!   the multi-channel node-feature layout: degree-major panels
//!   `[l][channel][m]`, each panel a contiguous `C x (2l+1)` block.
//! * A *path* is one `(segment, channel)` pair; paths are numbered
//!   segment-major ([`Irreps::n_paths`] total).  Per-path weight vectors
//!   (the paper's per-degree `w_l`, generalized to per-`(channel, l)`)
//!   use this order everywhere: for `spherical(C, L)` the weight of
//!   `(l, c)` sits at `l*C + c`, which for `C = 1` degenerates to the
//!   historical per-degree indexing.

use std::fmt;

use crate::err;
use crate::util::error::Result;
use crate::util::json::Json;

/// One `mul x l` run of identical irreps.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct IrrepSeg {
    /// multiplicity (number of channels of this degree)
    pub mul: usize,
    /// degree
    pub l: usize,
}

impl IrrepSeg {
    /// Coefficients per channel.
    #[inline]
    pub fn width(&self) -> usize {
        2 * self.l + 1
    }

    /// Total coefficients of the segment.
    #[inline]
    pub fn dim(&self) -> usize {
        self.mul * self.width()
    }
}

/// A typed feature layout: ordered `mul x l` segments with precomputed
/// offsets.  Cheap to clone; equality is structural.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Irreps {
    segs: Vec<IrrepSeg>,
    /// running start offset per segment (len = segs.len() + 1; the last
    /// entry is the total dimension)
    offsets: Vec<usize>,
}

impl Irreps {
    /// Build from `(mul, l)` pairs, in order.  Zero-multiplicity
    /// segments are dropped (they occupy no coefficients).
    pub fn new(segs: impl IntoIterator<Item = (usize, usize)>) -> Irreps {
        let segs: Vec<IrrepSeg> = segs
            .into_iter()
            .filter(|&(mul, _)| mul > 0)
            .map(|(mul, l)| IrrepSeg { mul, l })
            .collect();
        let mut offsets = Vec::with_capacity(segs.len() + 1);
        let mut at = 0usize;
        for s in &segs {
            offsets.push(at);
            at += s.dim();
        }
        offsets.push(at);
        Irreps { segs, offsets }
    }

    /// One channel of every degree `0..=l_max` — the historical
    /// `(L+1)^2` feature layout.
    pub fn single(l_max: usize) -> Irreps {
        Irreps::spherical(1, l_max)
    }

    /// `mul` channels of every degree `0..=l_max`, degree-major panels.
    pub fn spherical(mul: usize, l_max: usize) -> Irreps {
        Irreps::new((0..=l_max).map(|l| (mul, l)))
    }

    /// The segments, in layout order.
    pub fn segs(&self) -> &[IrrepSeg] {
        &self.segs
    }

    /// Total flat dimension.
    #[inline]
    pub fn dim(&self) -> usize {
        *self.offsets.last().unwrap()
    }

    /// Highest degree present (0 for the empty layout).
    pub fn l_max(&self) -> usize {
        self.segs.iter().map(|s| s.l).max().unwrap_or(0)
    }

    /// Number of `(segment, channel)` paths.
    pub fn n_paths(&self) -> usize {
        self.segs.iter().map(|s| s.mul).sum()
    }

    /// Start offset of segment `s`.
    #[inline]
    pub fn offset(&self, s: usize) -> usize {
        self.offsets[s]
    }

    /// Flat index range of channel `c` of segment `s` (one `2l+1` slot).
    #[inline]
    pub fn slot(&self, s: usize, c: usize) -> std::ops::Range<usize> {
        let seg = &self.segs[s];
        debug_assert!(c < seg.mul, "channel {c} out of range (mul {})",
                      seg.mul);
        let base = self.offsets[s] + c * seg.width();
        base..base + seg.width()
    }

    /// `Some(mul)` when every segment has the same multiplicity and the
    /// degrees are exactly `0..=l_max` in order — the layout
    /// [`Irreps::spherical`] produces.
    pub fn uniform_mul(&self) -> Option<usize> {
        let mul = self.segs.first()?.mul;
        for (l, s) in self.segs.iter().enumerate() {
            if s.mul != mul || s.l != l {
                return None;
            }
        }
        Some(mul)
    }

    /// The `mul = 1` version of this layout (what one gathered channel
    /// looks like).
    pub fn one_channel(&self) -> Irreps {
        Irreps::new(self.segs.iter().map(|s| (1, s.l)))
    }

    // --- path-weight ops (the shared per-degree scaling helper) ---

    /// `x[(s, c, m)] *= w[path(s, c)]` — the per-path reweighting used by
    /// the weighted Gaunt TP and the model's residual mixes.
    pub fn scale_paths_inplace(&self, x: &mut [f64], w: &[f64]) {
        debug_assert_eq!(x.len(), self.dim());
        debug_assert!(w.len() >= self.n_paths());
        let mut p = 0usize;
        for (s, seg) in self.segs.iter().enumerate() {
            let base = self.offsets[s];
            let wd = seg.width();
            for c in 0..seg.mul {
                let wv = w[p];
                p += 1;
                for v in x[base + c * wd..base + (c + 1) * wd].iter_mut() {
                    *v *= wv;
                }
            }
        }
    }

    /// `out[(s, c, m)] += w[path(s, c)] * x[(s, c, m)]` — scaled
    /// accumulate over the same layout.
    pub fn scale_paths_add(&self, w: &[f64], x: &[f64], out: &mut [f64]) {
        debug_assert_eq!(x.len(), self.dim());
        debug_assert_eq!(out.len(), self.dim());
        debug_assert!(w.len() >= self.n_paths());
        let mut p = 0usize;
        for (s, seg) in self.segs.iter().enumerate() {
            let base = self.offsets[s];
            let wd = seg.width();
            for c in 0..seg.mul {
                let wv = w[p];
                p += 1;
                let r = base + c * wd..base + (c + 1) * wd;
                for (o, v) in out[r.clone()].iter_mut().zip(&x[r]) {
                    *o += wv * v;
                }
            }
        }
    }

    /// `out_w[path(s, c)] += <g, x>_(s, c)` — per-path inner products,
    /// the exact adjoint of [`Irreps::scale_paths_add`] w.r.t. `w`.
    pub fn dot_paths_add(&self, g: &[f64], x: &[f64], out_w: &mut [f64]) {
        debug_assert_eq!(g.len(), self.dim());
        debug_assert_eq!(x.len(), self.dim());
        debug_assert!(out_w.len() >= self.n_paths());
        let mut p = 0usize;
        for (s, seg) in self.segs.iter().enumerate() {
            let base = self.offsets[s];
            let wd = seg.width();
            for c in 0..seg.mul {
                let r = base + c * wd..base + (c + 1) * wd;
                let mut acc = 0.0;
                for (gv, xv) in g[r.clone()].iter().zip(&x[r]) {
                    acc += gv * xv;
                }
                out_w[p] += acc;
                p += 1;
            }
        }
    }

    // --- channel views (multi-channel <-> single-channel staging) ---

    /// Copy channel `c` of every segment into `out`, which uses this
    /// layout's [`Irreps::one_channel`] ordering.  Requires `c <
    /// seg.mul` for every segment.
    pub fn gather_channel(&self, x: &[f64], c: usize, out: &mut [f64]) {
        debug_assert_eq!(x.len(), self.dim());
        let mut at = 0usize;
        for s in 0..self.segs.len() {
            let slot = self.slot(s, c);
            let wd = slot.len();
            out[at..at + wd].copy_from_slice(&x[slot]);
            at += wd;
        }
        // (allocation-free even under debug_assertions: this sits on the
        // model's per-edge hot path, which the counting-allocator
        // regression tests measure in the dev profile)
        debug_assert_eq!(
            at,
            self.segs.iter().map(|s| s.width()).sum::<usize>()
        );
    }

    /// Overwrite channel `c` of every segment from `src` (in
    /// [`Irreps::one_channel`] ordering).
    pub fn scatter_channel(&self, src: &[f64], c: usize, x: &mut [f64]) {
        debug_assert_eq!(x.len(), self.dim());
        let mut at = 0usize;
        for s in 0..self.segs.len() {
            let slot = self.slot(s, c);
            let wd = slot.len();
            x[slot].copy_from_slice(&src[at..at + wd]);
            at += wd;
        }
    }

    /// Accumulate `src` into channel `c` of every segment.
    pub fn scatter_channel_add(&self, src: &[f64], c: usize, x: &mut [f64]) {
        debug_assert_eq!(x.len(), self.dim());
        let mut at = 0usize;
        for s in 0..self.segs.len() {
            let slot = self.slot(s, c);
            let wd = slot.len();
            for (xv, sv) in x[slot].iter_mut().zip(&src[at..at + wd]) {
                *xv += sv;
            }
            at += wd;
        }
    }

    // --- text / JSON round trips ---

    /// Parse `"32x0 + 16x1 + 8x2"` (whitespace optional; a bare degree
    /// means multiplicity 1, so `"0+1+2"` is [`Irreps::single`]`(2)`).
    pub fn parse(text: &str) -> Result<Irreps> {
        let mut segs = Vec::new();
        for part in text.split('+') {
            let part = part.trim();
            if part.is_empty() {
                return Err(err!("irreps '{text}': empty segment"));
            }
            let (mul, l) = match part.split_once(['x', 'X']) {
                Some((m, l)) => (
                    m.trim().parse::<usize>().map_err(|_| {
                        err!("irreps '{text}': bad multiplicity '{m}'")
                    })?,
                    l.trim(),
                ),
                None => (1, part),
            };
            let l = l.parse::<usize>()
                .map_err(|_| err!("irreps '{text}': bad degree '{l}'"))?;
            segs.push((mul, l));
        }
        Ok(Irreps::new(segs))
    }

    /// JSON as an array of `[mul, l]` pairs.
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.segs
                .iter()
                .map(|s| Json::Arr(vec![
                    Json::Num(s.mul as f64),
                    Json::Num(s.l as f64),
                ]))
                .collect(),
        )
    }

    /// Rebuild from [`Irreps::to_json`] output.
    pub fn from_json(doc: &Json) -> Result<Irreps> {
        let arr = doc.as_arr().ok_or_else(|| err!("irreps: not an array"))?;
        let mut segs = Vec::with_capacity(arr.len());
        for pair in arr {
            let mul = pair.idx(0).and_then(Json::as_usize)
                .ok_or_else(|| err!("irreps: bad [mul, l] pair"))?;
            let l = pair.idx(1).and_then(Json::as_usize)
                .ok_or_else(|| err!("irreps: bad [mul, l] pair"))?;
            segs.push((mul, l));
        }
        Ok(Irreps::new(segs))
    }
}

impl fmt::Display for Irreps {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, s) in self.segs.iter().enumerate() {
            if i > 0 {
                write!(f, " + ")?;
            }
            write!(f, "{}x{}", s.mul, s.l)?;
        }
        if self.segs.is_empty() {
            write!(f, "0x0")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::{lm_index, num_coeffs};

    #[test]
    fn single_matches_lm_index_layout() {
        let ir = Irreps::single(3);
        assert_eq!(ir.dim(), num_coeffs(3));
        assert_eq!(ir.l_max(), 3);
        assert_eq!(ir.n_paths(), 4);
        for l in 0..=3usize {
            assert_eq!(ir.offset(l), lm_index(l, -(l as i64)));
            assert_eq!(ir.slot(l, 0),
                       lm_index(l, -(l as i64))..lm_index(l, l as i64) + 1);
        }
        assert_eq!(ir.uniform_mul(), Some(1));
    }

    #[test]
    fn spherical_layout_offsets_and_paths() {
        let ir = Irreps::spherical(4, 2);
        assert_eq!(ir.dim(), 4 * num_coeffs(2));
        assert_eq!(ir.n_paths(), 12);
        // degree-major panels: [l=0: 4x1][l=1: 4x3][l=2: 4x5]
        assert_eq!(ir.offset(0), 0);
        assert_eq!(ir.offset(1), 4);
        assert_eq!(ir.offset(2), 4 + 12);
        assert_eq!(ir.slot(1, 2), 4 + 6..4 + 9);
        assert_eq!(ir.uniform_mul(), Some(4));
        assert_eq!(ir.one_channel(), Irreps::single(2));
    }

    #[test]
    fn parse_display_round_trip() {
        for text in ["32x0 + 16x1 + 8x2", "1x0", "2x0 + 2x1 + 2x2 + 2x3"] {
            let ir = Irreps::parse(text).unwrap();
            assert_eq!(format!("{ir}"), text);
            assert_eq!(Irreps::parse(&format!("{ir}")).unwrap(), ir);
        }
        // bare degrees mean mul = 1; zero-mul segments are dropped
        assert_eq!(Irreps::parse("0+1+2").unwrap(), Irreps::single(2));
        assert_eq!(Irreps::parse("3x1 + 0x2").unwrap(),
                   Irreps::new([(3, 1)]));
        assert!(Irreps::parse("3y2").is_err());
        assert!(Irreps::parse("3x").is_err());
        assert!(Irreps::parse("").is_err());
    }

    #[test]
    fn json_round_trip() {
        let ir = Irreps::new([(32, 0), (16, 1), (8, 2)]);
        let back = Irreps::from_json(&ir.to_json()).unwrap();
        assert_eq!(ir, back);
        assert!(Irreps::from_json(&Json::Num(3.0)).is_err());
    }

    #[test]
    fn non_uniform_is_detected() {
        assert_eq!(Irreps::new([(32, 0), (16, 1)]).uniform_mul(), None);
        assert_eq!(Irreps::new([(2, 0), (2, 2)]).uniform_mul(), None);
        assert_eq!(Irreps::new([(2, 1), (2, 0)]).uniform_mul(), None);
    }

    #[test]
    fn path_scaling_matches_manual_loops() {
        let mut rng = Rng::new(0);
        let ir = Irreps::spherical(3, 2);
        let x = rng.normals(ir.dim());
        let w = rng.normals(ir.n_paths());
        // scale_paths_inplace vs elementwise reference
        let mut got = x.clone();
        ir.scale_paths_inplace(&mut got, &w);
        for (s, seg) in ir.segs().iter().enumerate() {
            for c in 0..seg.mul {
                for i in ir.slot(s, c) {
                    let want = x[i] * w[s * seg.mul + c];
                    assert_eq!(got[i], want);
                }
            }
        }
        // scale_paths_add == base + scaled
        let base = rng.normals(ir.dim());
        let mut acc = base.clone();
        ir.scale_paths_add(&w, &x, &mut acc);
        for i in 0..ir.dim() {
            assert!((acc[i] - (base[i] + got[i])).abs() < 1e-15);
        }
        // dot_paths_add is the w-adjoint of scale_paths_add
        let g = rng.normals(ir.dim());
        let mut wg = vec![0.0; ir.n_paths()];
        ir.dot_paths_add(&g, &x, &mut wg);
        // <g, w (.) x> = <wg, w> for every w
        let lhs: f64 = g.iter().zip(&got).map(|(a, b)| a * b).sum();
        let rhs: f64 = wg.iter().zip(&w).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-10 * (1.0 + lhs.abs()));
    }

    #[test]
    fn single_channel_paths_are_per_degree() {
        // for mul = 1 the path ops reduce to the historical per-degree
        // scaling on the lm_index layout
        let mut rng = Rng::new(1);
        let l_max = 3usize;
        let ir = Irreps::single(l_max);
        let x = rng.normals(ir.dim());
        let w = rng.normals(l_max + 1);
        let mut got = x.clone();
        ir.scale_paths_inplace(&mut got, &w);
        for l in 0..=l_max {
            for m in -(l as i64)..=(l as i64) {
                let i = lm_index(l, m);
                assert_eq!(got[i], x[i] * w[l]);
            }
        }
    }

    #[test]
    fn gather_scatter_round_trip() {
        let mut rng = Rng::new(2);
        let ir = Irreps::spherical(3, 2);
        let nf = num_coeffs(2);
        let x = rng.normals(ir.dim());
        let mut chans = vec![vec![0.0; nf]; 3];
        for (c, ch) in chans.iter_mut().enumerate() {
            ir.gather_channel(&x, c, ch);
        }
        // gathered channel c of degree l equals the [l][c][m] panel slice
        for (s, seg) in ir.segs().iter().enumerate() {
            for c in 0..seg.mul {
                let single_off = Irreps::single(ir.l_max()).offset(s);
                assert_eq!(
                    &chans[c][single_off..single_off + seg.width()],
                    &x[ir.slot(s, c)]
                );
            }
        }
        // scatter rebuilds the exact original
        let mut back = vec![0.0; ir.dim()];
        for (c, ch) in chans.iter().enumerate() {
            ir.scatter_channel(ch, c, &mut back);
        }
        assert_eq!(back, x);
        // scatter_add doubles
        for (c, ch) in chans.iter().enumerate() {
            ir.scatter_channel_add(ch, c, &mut back);
        }
        for (b, xv) in back.iter().zip(&x) {
            assert!((b - 2.0 * xv).abs() < 1e-15);
        }
    }

    #[test]
    fn mul_one_gather_is_identity() {
        let mut rng = Rng::new(3);
        let ir = Irreps::single(2);
        let x = rng.normals(ir.dim());
        let mut out = vec![0.0; ir.dim()];
        ir.gather_channel(&x, 0, &mut out);
        assert_eq!(out, x);
    }
}
