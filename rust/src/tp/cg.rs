//! The Clebsch-Gordan full tensor product — the paper's O(L^6) baseline
//! (Eqn. (1)), in the two forms real implementations use:
//!
//! * dense contraction over the full coupling tensor, and
//! * sparse iteration over the non-zero coefficients (what e3nn's
//!   compiled tensor product effectively does).

use crate::so3::gaunt::{cg_tensor_real, sparsify};
use crate::num_coeffs;

/// Precomputed CG tensor-product plan for fixed (L1, L2, L3).
pub struct CgPlan {
    pub l1: usize,
    pub l2: usize,
    pub l3: usize,
    n1: usize,
    n2: usize,
    n3: usize,
    dense: Vec<f64>,
    sparse: Vec<(u32, u32, u32, f64)>,
}

impl CgPlan {
    pub fn new(l1: usize, l2: usize, l3: usize) -> Self {
        let dense = cg_tensor_real(l1, l2, l3);
        let (n1, n2, n3) = (num_coeffs(l1), num_coeffs(l2), num_coeffs(l3));
        let sparse = sparsify(&dense, n3, n1, n2);
        CgPlan { l1, l2, l3, n1, n2, n3, dense, sparse }
    }

    /// Number of non-zero coupling coefficients (the true O(L^6) witness).
    pub fn nnz(&self) -> usize {
        self.sparse.len()
    }

    /// Dense contraction (cache-friendly triple loop).
    pub fn apply_dense(&self, x1: &[f64], x2: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.n3];
        for (k, o) in out.iter_mut().enumerate() {
            let block = &self.dense[k * self.n1 * self.n2..];
            let mut acc = 0.0;
            for (i, xi) in x1.iter().enumerate() {
                if *xi == 0.0 {
                    continue;
                }
                let row = &block[i * self.n2..(i + 1) * self.n2];
                let mut s = 0.0;
                for (j, xj) in x2.iter().enumerate() {
                    s += row[j] * xj;
                }
                acc += xi * s;
            }
            *o = acc;
        }
        out
    }

    /// Sparse contraction over the non-zero coefficients.
    pub fn apply_sparse(&self, x1: &[f64], x2: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.n3];
        self.apply_sparse_into(x1, x2, &mut out);
        out
    }

    /// [`CgPlan::apply_sparse`] into a caller buffer (overwritten).
    /// Allocation-free.
    pub fn apply_sparse_into(&self, x1: &[f64], x2: &[f64], out: &mut [f64]) {
        out[..self.n3].fill(0.0);
        for (k, i, j, v) in &self.sparse {
            out[*k as usize] += v * x1[*i as usize] * x2[*j as usize];
        }
    }

    /// Exact VJP w.r.t. the first operand: `grad[i] = sum_{k,j}
    /// C[k,i,j] g[k] x2[j]` over the same sparse coefficient list.
    /// Overwrites `grad`; allocation-free.
    pub fn vjp_x1_into(&self, g: &[f64], x2: &[f64], grad: &mut [f64]) {
        grad[..self.n1].fill(0.0);
        for (k, i, j, v) in &self.sparse {
            grad[*i as usize] += v * g[*k as usize] * x2[*j as usize];
        }
    }

    /// Batched sparse apply.
    pub fn apply_batch(&self, x1: &[f64], x2: &[f64], rows: usize) -> Vec<f64> {
        let mut out = vec![0.0; rows * self.n3];
        for r in 0..rows {
            let o = &mut out[r * self.n3..(r + 1) * self.n3];
            let a = &x1[r * self.n1..(r + 1) * self.n1];
            let b = &x2[r * self.n2..(r + 1) * self.n2];
            for (k, i, j, v) in &self.sparse {
                o[*k as usize] += v * a[*i as usize] * b[*j as usize];
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::so3::linalg::matvec;
    use crate::so3::rotation::{wigner_d_real_block, Rot3};
    use crate::util::prop::max_abs_diff;
    use crate::util::rng::Rng;
    use crate::lm_index;

    #[test]
    fn sparse_matches_dense() {
        let mut rng = Rng::new(0);
        for (l1, l2, l3) in [(1usize, 1usize, 2usize), (2, 2, 2), (3, 2, 4)] {
            let plan = CgPlan::new(l1, l2, l3);
            let x1 = rng.normals(num_coeffs(l1));
            let x2 = rng.normals(num_coeffs(l2));
            let a = plan.apply_dense(&x1, &x2);
            let b = plan.apply_sparse(&x1, &x2);
            assert!(max_abs_diff(&a, &b) < 1e-12);
        }
    }

    #[test]
    fn equivariant() {
        let mut rng = Rng::new(1);
        let l = 2usize;
        let rot = Rot3::random(&mut rng);
        let d = wigner_d_real_block(l, &rot);
        let d_out = wigner_d_real_block(2 * l, &rot);
        let plan = CgPlan::new(l, l, 2 * l);
        let n = num_coeffs(l);
        let x1 = rng.normals(n);
        let x2 = rng.normals(n);
        let a = plan.apply_sparse(&matvec(&d, &x1, n, n), &matvec(&d, &x2, n, n));
        let b0 = plan.apply_sparse(&x1, &x2);
        let nn = num_coeffs(2 * l);
        let b = matvec(&d_out, &b0, nn, nn);
        assert!(max_abs_diff(&a, &b) < 1e-8);
    }

    #[test]
    fn includes_odd_parity_paths_gaunt_excludes() {
        // pure (1,1)->1 (cross product) is present in CG, absent in Gaunt
        let plan = CgPlan::new(1, 1, 1);
        let mut x1 = vec![0.0; 4];
        let mut x2 = vec![0.0; 4];
        x1[lm_index(1, 1)] = 1.0; // x-direction
        x2[lm_index(1, -1)] = 1.0; // y-direction
        let out = plan.apply_sparse(&x1, &x2);
        let l1_norm: f64 = out[1..4].iter().map(|v| v * v).sum();
        assert!(l1_norm > 1e-6, "CG (1,1)->1 path missing");
        let gplan = crate::tp::GauntPlan::new(1, 1, 1,
                                              crate::tp::ConvMethod::Direct);
        let gout = gplan.apply(&x1, &x2);
        let g_norm: f64 = gout[1..4].iter().map(|v| v * v).sum();
        assert!(g_norm < 1e-12, "Gaunt should kill odd parity");
    }

    #[test]
    fn nnz_grows_like_l6() {
        // sanity on the complexity witness: nnz(L)/nnz(L-1) should grow
        let n2 = CgPlan::new(2, 2, 2).nnz();
        let n4 = CgPlan::new(4, 4, 4).nnz();
        assert!(n4 > 8 * n2, "nnz {n2} -> {n4}");
    }

    #[test]
    fn batch_matches_single() {
        let mut rng = Rng::new(2);
        let plan = CgPlan::new(2, 2, 2);
        let n = num_coeffs(2);
        let x1 = rng.normals(3 * n);
        let x2 = rng.normals(3 * n);
        let batch = plan.apply_batch(&x1, &x2, 3);
        for r in 0..3 {
            let single =
                plan.apply_sparse(&x1[r * n..(r + 1) * n], &x2[r * n..(r + 1) * n]);
            assert!(max_abs_diff(&batch[r * n..(r + 1) * n], &single) < 1e-12);
        }
    }
}
