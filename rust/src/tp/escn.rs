//! Equivariant Convolutions: feature (x) spherical-harmonic filter.
//!
//! Both implementations exploit the Passaro & Zitnick (eSCN) observation:
//! rotating the edge direction onto the pole makes the filter's SH
//! coefficients proportional to delta_{m,0}.
//!
//! * [`EscnPlan`] — the eSCN baseline: in the aligned frame the CG
//!   contraction becomes SO(2)-diagonal (a 2x2 block per |m|: even-parity
//!   paths couple m -> m, odd-parity paths couple m -> -m).
//! * [`GauntConvPlan`] — the paper's accelerated variant: run the Gaunt
//!   Fourier pipeline in the aligned frame, where the filter's 2D Fourier
//!   grid has a single non-zero column (v = 0), cutting the filter
//!   conversion to O(L^2) and the convolution loop to a single-column
//!   sweep (paper Sec. 3.3, Eqn. (58)).
//!
//! Our SH convention puts the m = 0 sparsity on the +z pole, so the
//! alignment rotation sends the edge to +z (eSCN's paper uses +y; the two
//! differ by a fixed frame change and are operationally identical).

use crate::so3::gaunt::cg_tensor_real;
use crate::so3::rotation::{
    align_to_y, wigner_d_real_block_into, Rot3, WignerScratch,
};
use crate::so3::sh::{real_sh_all_xyz, sh_norm};
use crate::so3::linalg::matvec_into;
use crate::tp::gaunt::ConvMethod;
use crate::fourier::complex::C64;
use crate::fourier::plan::{ConvPlan, ConvScratch};
use crate::fourier::tables::{f2sh_contract, sh2f_panels, theta_fourier,
                             F2shPanelsT, Sh2fPanels};
use crate::tp::gaunt::GauntPlan;
use crate::{lm_index, num_coeffs};

/// Rotation sending `dir` to the +z pole.
pub fn align_to_z(dir: [f64; 3]) -> Rot3 {
    let y2z = Rot3([[1.0, 0.0, 0.0], [0.0, 0.0, -1.0], [0.0, 1.0, 0.0]]);
    y2z * align_to_y(dir)
}

/// One SO(2)-diagonal coupling path in the aligned frame.
#[derive(Clone, Debug)]
#[allow(dead_code)] // l2 kept for debugging/reporting
struct Path {
    l1: usize,
    l2: usize,
    l3: usize,
    /// per-|m| (0..=min(l1,l3)) diagonal and antidiagonal coefficients,
    /// filter magnitude Y_{l2,0}(z) folded in.
    diag: Vec<f64>,
    anti: Vec<f64>,
}

/// eSCN-style equivariant convolution plan.
pub struct EscnPlan {
    pub l_in: usize,
    pub l_filter: usize,
    pub l_out: usize,
    paths: Vec<Path>,
}

/// Caller-owned scratch for [`EscnPlan`]'s full (rotated) convolution
/// and its VJP: Wigner-D staging + rotated feature buffers, one per
/// worker thread.
pub struct EscnScratch {
    /// block Wigner-D staging (max of input/output block sizes)
    d_blk: Vec<f64>,
    /// aligned-frame input feature
    x_rot: Vec<f64>,
    /// aligned-frame output feature
    y_rot: Vec<f64>,
    /// Wigner-D evaluation workspace
    wig: WignerScratch,
}

impl EscnPlan {
    pub fn new(l_in: usize, l_filter: usize, l_out: usize) -> Self {
        let c = cg_tensor_real(l_in, l_filter, l_out);
        let (n1, n2) = (num_coeffs(l_in), num_coeffs(l_filter));
        let mut paths = Vec::new();
        for l1 in 0..=l_in {
            for l2 in 0..=l_filter {
                for l3 in l1.abs_diff(l2)..=(l1 + l2).min(l_out) {
                    let f_mag = ((2 * l2 + 1) as f64
                        / (4.0 * std::f64::consts::PI))
                        .sqrt(); // Y_{l2,0}(+z)
                    let mm = l1.min(l3);
                    let mut diag = vec![0.0; mm + 1];
                    let mut anti = vec![0.0; mm + 1];
                    let j0 = lm_index(l2, 0);
                    for m in 0..=(mm as i64) {
                        let k = lm_index(l3, m);
                        diag[m as usize] = c
                            [(k * n1 + lm_index(l1, m)) * n2 + j0]
                            * f_mag;
                        if m > 0 {
                            anti[m as usize] = c
                                [(k * n1 + lm_index(l1, -m)) * n2 + j0]
                                * f_mag;
                        }
                    }
                    if diag.iter().chain(&anti).any(|v| v.abs() > 1e-14) {
                        paths.push(Path { l1, l2, l3, diag, anti });
                    }
                }
            }
        }
        EscnPlan { l_in, l_filter, l_out, paths }
    }

    pub fn n_paths(&self) -> usize {
        self.paths.len()
    }

    /// Contraction in the ALIGNED frame (filter = sum_l2 h-weighted Y(z)).
    /// `h[(l1, l2, l3)]` are per-path weights in path order.
    pub fn apply_aligned(&self, x: &[f64], h: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; num_coeffs(self.l_out)];
        self.apply_aligned_into(x, h, &mut out);
        out
    }

    /// [`EscnPlan::apply_aligned`] into a caller buffer (overwritten).
    /// Allocation-free.
    pub fn apply_aligned_into(&self, x: &[f64], h: &[f64], out: &mut [f64]) {
        debug_assert_eq!(h.len(), self.paths.len());
        out[..num_coeffs(self.l_out)].fill(0.0);
        for (p, w) in self.paths.iter().zip(h) {
            if *w == 0.0 {
                continue;
            }
            let mm = p.l1.min(p.l3);
            // m = 0
            out[lm_index(p.l3, 0)] += w * p.diag[0] * x[lm_index(p.l1, 0)];
            for m in 1..=(mm as i64) {
                let (d, a) = (p.diag[m as usize], p.anti[m as usize]);
                let (xp, xm) = (x[lm_index(p.l1, m)], x[lm_index(p.l1, -m)]);
                // even parity: m -> m; odd parity: m -> -m (SO(2) 2x2 block)
                out[lm_index(p.l3, m)] += w * (d * xp + a * xm);
                out[lm_index(p.l3, -m)] += w * (d * xm - a * xp);
            }
        }
    }

    /// Exact transpose of [`EscnPlan::apply_aligned_into`] in its first
    /// argument: `out = A(h)^T g`.  The aligned contraction is linear in
    /// `x`, so this IS the aligned-frame VJP.  Allocation-free.
    pub fn apply_aligned_transpose_into(
        &self, g: &[f64], h: &[f64], out: &mut [f64],
    ) {
        debug_assert_eq!(h.len(), self.paths.len());
        out[..num_coeffs(self.l_in)].fill(0.0);
        for (p, w) in self.paths.iter().zip(h) {
            if *w == 0.0 {
                continue;
            }
            let mm = p.l1.min(p.l3);
            out[lm_index(p.l1, 0)] += w * p.diag[0] * g[lm_index(p.l3, 0)];
            for m in 1..=(mm as i64) {
                let (d, a) = (p.diag[m as usize], p.anti[m as usize]);
                let (gp, gm) = (g[lm_index(p.l3, m)], g[lm_index(p.l3, -m)]);
                // transpose of the forward 2x2 block
                out[lm_index(p.l1, m)] += w * (d * gp - a * gm);
                out[lm_index(p.l1, -m)] += w * (a * gp + d * gm);
            }
        }
    }

    /// Full edge convolution: rotate into the aligned frame, contract,
    /// rotate back.  `dir` is the edge direction, `h` per-path weights.
    pub fn apply(&self, x: &[f64], dir: [f64; 3], h: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; num_coeffs(self.l_out)];
        let mut scratch = self.scratch();
        self.apply_into(x, dir, h, &mut out, &mut scratch);
        out
    }

    /// Fresh scratch for the allocation-free rotation round trip (one
    /// per worker thread).
    pub fn scratch(&self) -> EscnScratch {
        let n_in = num_coeffs(self.l_in);
        let n_out = num_coeffs(self.l_out);
        EscnScratch {
            d_blk: vec![0.0; (n_in * n_in).max(n_out * n_out)],
            x_rot: vec![0.0; n_in],
            y_rot: vec![0.0; n_out],
            wig: WignerScratch::new(self.l_in.max(self.l_out)),
        }
    }

    /// [`EscnPlan::apply`] over caller scratch: alignment rotation,
    /// aligned SO(2) contraction, inverse rotation — zero steady-state
    /// allocations once the per-degree Wigner fit caches are warm.
    pub fn apply_into(
        &self, x: &[f64], dir: [f64; 3], h: &[f64], out: &mut [f64],
        s: &mut EscnScratch,
    ) {
        let rot = align_to_z(dir);
        let n_in = num_coeffs(self.l_in);
        let n_out = num_coeffs(self.l_out);
        wigner_d_real_block_into(self.l_in, &rot, &mut s.d_blk, &mut s.wig);
        matvec_into(&s.d_blk, x, n_in, n_in, &mut s.x_rot);
        // split borrows: contract from x_rot into y_rot
        let (x_rot, y_rot) = (&s.x_rot, &mut s.y_rot);
        self.apply_aligned_into(x_rot, h, y_rot);
        wigner_d_real_block_into(self.l_out, &rot.transpose(), &mut s.d_blk,
                                 &mut s.wig);
        matvec_into(&s.d_blk, &s.y_rot, n_out, n_out, &mut out[..n_out]);
    }

    /// Exact VJP of [`EscnPlan::apply_into`] w.r.t. the input feature:
    /// the full convolution is `M x` with `M = D_out(R^T) A(h) D_in(R)`,
    /// and the real Wigner blocks are orthogonal (`D(R)^T = D(R^T)`), so
    /// `M^T g = D_in(R^T) A(h)^T D_out(R) g`.  Allocation-free over the
    /// same scratch.
    pub fn vjp_into(
        &self, dir: [f64; 3], h: &[f64], g: &[f64], grad: &mut [f64],
        s: &mut EscnScratch,
    ) {
        let rot = align_to_z(dir);
        let n_in = num_coeffs(self.l_in);
        let n_out = num_coeffs(self.l_out);
        wigner_d_real_block_into(self.l_out, &rot, &mut s.d_blk, &mut s.wig);
        matvec_into(&s.d_blk, g, n_out, n_out, &mut s.y_rot);
        let (y_rot, x_rot) = (&s.y_rot, &mut s.x_rot);
        self.apply_aligned_transpose_into(y_rot, h, x_rot);
        wigner_d_real_block_into(self.l_in, &rot.transpose(), &mut s.d_blk,
                                 &mut s.wig);
        matvec_into(&s.d_blk, &s.x_rot, n_in, n_in, &mut grad[..n_in]);
    }

    /// Batched full convolution: row `r` convolves `x[r]` along `dirs[r]`
    /// with shared path weights `h` (rows of x are independent edges).
    pub fn apply_batch(
        &self, x: &[f64], dirs: &[[f64; 3]], h: &[f64],
    ) -> Vec<f64> {
        let n_in = num_coeffs(self.l_in);
        let n_out = num_coeffs(self.l_out);
        let rows = dirs.len();
        debug_assert_eq!(x.len(), rows * n_in);
        let mut out = vec![0.0; rows * n_out];
        for (r, dir) in dirs.iter().enumerate() {
            let y = self.apply(&x[r * n_in..(r + 1) * n_in], *dir, h);
            out[r * n_out..(r + 1) * n_out].copy_from_slice(&y);
        }
        out
    }
}

/// Degree sum at and above which [`GauntConvPlan::apply_aligned`] routes
/// through the cached-spectrum FFT path instead of the direct
/// single-column sweep.
///
/// The aligned filter's single Fourier column makes the direct sweep
/// O(L^3) with a tiny constant (~8 (2Lf+1)(2Li+1)^2 flops), so the FFT
/// path — ~17.5 m^2 log2 m with m = 2^ceil(log2(2(Li+Lf)+1)) — only
/// catches up around l_in + l_filter ~ 36 on the flop model.
/// `fig1b_equivariant_convolution` benches both so the constant can be
/// re-pinned from measurement.
pub const GAUNT_CONV_FFT_CROSSOVER: usize = 36;

/// Caller-owned scratch for [`GauntConvPlan`] applies: one per worker
/// thread.  Direct-sweep buffers are sized up front; the FFT-path
/// workspaces grow on the first FFT-path call and are never resized
/// after, so steady state is allocation-free on either path.  The
/// rotation round trip ([`GauntConvPlan::apply_full_into`]) reuses the
/// Wigner-D buffers held here, so the FULL per-edge convolution —
/// alignment, aligned contraction, inverse rotation — is allocation-free
/// once the per-degree Wigner fit caches are warm.
pub struct GauntConvScratch {
    /// sh2f staging
    w: Vec<C64>,
    /// input Fourier grid (2 l_in + 1)^2
    u1: Vec<C64>,
    /// combined filter column (2 l_filter + 1)
    fcol: Vec<C64>,
    /// product grid (2 n_grid + 1)^2
    u3: Vec<C64>,
    /// input sample array (m^2, FFT path)
    f1: Vec<f64>,
    /// combined filter profile (m, FFT path)
    prof: Vec<f64>,
    /// planned-convolution workspace
    conv: ConvScratch,
    /// block Wigner-D staging (max of input/output block sizes)
    d_blk: Vec<f64>,
    /// rotated input feature
    x_rot: Vec<f64>,
    /// aligned-frame output feature
    y_rot: Vec<f64>,
    /// Wigner-D evaluation workspace
    wig: WignerScratch,
}

/// Gaunt-accelerated equivariant convolution (paper Sec. 3.3).
///
/// Besides the conversion tables, the plan caches the aligned filter's
/// FORWARD SPECTRUM at build time: the filter's Fourier grid has a
/// single non-zero column (v = 0), so its real sample array is a 1D
/// profile per filter degree (`phi[l2][j]`), independent of the second
/// grid axis.  The FFT apply path never transforms the filter — it
/// combines the cached profiles with the per-call `h2` weights in
/// O(L m) and row-scales the input's sample array.
pub struct GauntConvPlan {
    pub l_in: usize,
    pub l_filter: usize,
    pub l_out: usize,
    p_in: Sh2fPanels,
    t_out: F2shPanelsT,
    /// theta-Fourier columns of the aligned filter per degree l2:
    /// col[l2][u] over u = -l2..l2 (filter magnitude folded in).
    filter_cols: Vec<Vec<C64>>,
    /// planned convolution workspace (wrap maps + shared FFT tables)
    conv: ConvPlan,
    /// cached filter sample profiles phi[l2][j] = Re INV[col_l2](j),
    /// length m each — the filter's FFT, done once at plan build.
    phi: Vec<Vec<f64>>,
    n_grid: usize,
}

impl GauntConvPlan {
    pub fn new(l_in: usize, l_filter: usize, l_out: usize) -> Self {
        let n_grid = l_in + l_filter;
        let conv = ConvPlan::new(2 * l_in + 1, 2 * l_filter + 1);
        let m = conv.m;
        let mut filter_cols = Vec::with_capacity(l_filter + 1);
        let mut phi = Vec::with_capacity(l_filter + 1);
        for l2 in 0..=l_filter {
            // aligned filter coefficient: x_{l2,0} = Y_{l2,0}(+z) = sqrt((2l+1)/4pi)
            let mag = sh_norm(l2, 0) * crate::so3::sh::assoc_legendre(l2, 0, 1.0);
            let col: Vec<C64> =
                theta_fourier(l2, 0).iter().map(|c| c.scale(mag)).collect();
            // phi_l2(j) = Re sum_u col[u] e^{+2 pi i u j / m}: the filter
            // column's (real) sample profile on the wrapped torus grid
            let prof: Vec<f64> = (0..m)
                .map(|j| {
                    let mut acc = C64::default();
                    for (k, c) in col.iter().enumerate() {
                        let u = k as f64 - l2 as f64;
                        acc += *c * C64::cis(
                            2.0 * std::f64::consts::PI * u * j as f64
                                / m as f64,
                        );
                    }
                    acc.re
                })
                .collect();
            filter_cols.push(col);
            phi.push(prof);
        }
        GauntConvPlan {
            l_in,
            l_filter,
            l_out,
            p_in: sh2f_panels(l_in),
            t_out: F2shPanelsT::build(l_out, n_grid),
            filter_cols,
            conv,
            phi,
            n_grid,
        }
    }

    /// Fresh scratch sized for this plan (one per worker thread).  The
    /// FFT-path buffers (`f1`, `prof`, the conv workspace) start empty
    /// and are grown on the first `apply_aligned_fft_into` call — plans
    /// below the crossover never touch them, so per-worker memory stays
    /// proportional to the path actually taken.
    pub fn scratch(&self) -> GauntConvScratch {
        let nl = self.l_in + 1;
        let n1 = 2 * self.l_in + 1;
        let nf = 2 * self.l_filter + 1;
        let nu3 = 2 * self.n_grid + 1;
        let n_in = num_coeffs(self.l_in);
        let n_out = num_coeffs(self.l_out);
        let n_blk = (n_in * n_in).max(n_out * n_out);
        GauntConvScratch {
            w: vec![C64::default(); nl * nl],
            u1: vec![C64::default(); n1 * n1],
            fcol: vec![C64::default(); nf],
            u3: vec![C64::default(); nu3 * nu3],
            f1: Vec::new(),
            prof: Vec::new(),
            conv: ConvScratch::empty(),
            d_blk: vec![0.0; n_blk],
            x_rot: vec![0.0; n_in],
            y_rot: vec![0.0; n_out],
            wig: WignerScratch::new(self.l_in.max(self.l_out)),
        }
    }

    /// Aligned-frame fast path: full sh2f on x, O(L^2) filter conversion,
    /// single-column convolution (or the cached-spectrum FFT path above
    /// the crossover), f2sh.
    /// `h2[l2]` are per-filter-degree weights (the paper's w_{l2}).
    pub fn apply_aligned(&self, x: &[f64], h2: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; num_coeffs(self.l_out)];
        let mut scratch = self.scratch();
        self.apply_aligned_into(x, h2, &mut out, &mut scratch);
        out
    }

    /// Aligned-frame fast path over caller scratch — the ONE place the
    /// direct-vs-FFT crossover dispatch lives.
    pub fn apply_aligned_into(
        &self, x: &[f64], h2: &[f64], out: &mut [f64],
        scratch: &mut GauntConvScratch,
    ) {
        if self.l_in + self.l_filter >= GAUNT_CONV_FFT_CROSSOVER {
            self.apply_aligned_fft_into(x, h2, out, scratch);
        } else {
            self.apply_aligned_direct_into(x, h2, out, scratch);
        }
    }

    /// Direct single-column sweep (the small-L winner).
    pub fn apply_aligned_direct(&self, x: &[f64], h2: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; num_coeffs(self.l_out)];
        let mut scratch = self.scratch();
        self.apply_aligned_direct_into(x, h2, &mut out, &mut scratch);
        out
    }

    /// [`GauntConvPlan::apply_aligned_direct`] over caller scratch:
    /// allocation-free.
    pub fn apply_aligned_direct_into(
        &self, x: &[f64], h2: &[f64], out: &mut [f64],
        scratch: &mut GauntConvScratch,
    ) {
        GauntPlan::sh2f_into(&self.p_in, x, &mut scratch.u1, &mut scratch.w);
        let u1 = &scratch.u1;
        let n1 = 2 * self.l_in + 1;
        // filter column F[u], u = -l_filter..l_filter, v = 0 only
        let nf = 2 * self.l_filter + 1;
        let fcol = &mut scratch.fcol;
        fcol.fill(C64::default());
        for (l2, col) in self.filter_cols.iter().enumerate() {
            let w = h2[l2];
            if w == 0.0 {
                continue;
            }
            for (k, v) in col.iter().enumerate() {
                fcol[self.l_filter - l2 + k] += v.scale(w);
            }
        }
        // single-column convolution: U3[u3, N+v'] = sum_u2 F[u2] U1[u3-u2, c1+v']
        let n = self.n_grid;
        let nu3 = 2 * n + 1;
        let u3 = &mut scratch.u3;
        u3.fill(C64::default());
        for u2 in 0..nf {
            let f = fcol[u2];
            if f.norm_sqr() == 0.0 {
                continue;
            }
            for ua in 0..n1 {
                let dst = (ua + u2) * nu3;
                let src = ua * n1;
                for v in 0..n1 {
                    // v offset: input v index 0..n1 maps to grid v index
                    // (n - l_in + v)
                    u3[dst + (n - self.l_in + v)] += f * u1[src + v];
                }
            }
        }
        f2sh_contract(&self.t_out, u3, out);
    }

    /// Cached-spectrum FFT path: transform the input grid to real
    /// samples, row-scale by the h2-combined cached filter profile (the
    /// filter itself is never transformed at apply time), transform
    /// back, project.
    pub fn apply_aligned_fft(&self, x: &[f64], h2: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; num_coeffs(self.l_out)];
        let mut scratch = self.scratch();
        self.apply_aligned_fft_into(x, h2, &mut out, &mut scratch);
        out
    }

    /// [`GauntConvPlan::apply_aligned_fft`] over caller scratch:
    /// allocation-free.
    pub fn apply_aligned_fft_into(
        &self, x: &[f64], h2: &[f64], out: &mut [f64],
        scratch: &mut GauntConvScratch,
    ) {
        let m = self.conv.m;
        // lazily sized: only this path pays for the m^2 workspaces, and
        // only on its first use (steady state stays allocation-free)
        if scratch.f1.len() != m * m {
            scratch.f1.resize(m * m, 0.0);
            scratch.prof.resize(m, 0.0);
            scratch.conv.ensure(m);
        }
        GauntPlan::sh2f_into(&self.p_in, x, &mut scratch.u1, &mut scratch.w);
        self.conv
            .samples_op1_into(&scratch.u1, &mut scratch.f1, &mut scratch.conv);
        // h2-weighted cached filter profile
        let f1 = &mut scratch.f1;
        let prof = &mut scratch.prof;
        prof.fill(0.0);
        for (l2, p) in self.phi.iter().enumerate() {
            let w = h2[l2];
            if w == 0.0 {
                continue;
            }
            for (a, b) in prof.iter_mut().zip(p) {
                *a += w * *b;
            }
        }
        // q(j, k) = f1(j, k) * phi(j): the filter's samples are constant
        // along the second axis (single non-zero Fourier column)
        for j in 0..m {
            let pj = prof[j];
            for v in f1[j * m..(j + 1) * m].iter_mut() {
                *v *= pj;
            }
        }
        self.conv
            .grid_from_samples_into(&scratch.f1, &mut scratch.u3, &mut scratch.conv);
        f2sh_contract(&self.t_out, &scratch.u3, out);
    }

    /// Full edge convolution with rotation round trip.
    pub fn apply(&self, x: &[f64], dir: [f64; 3], h2: &[f64]) -> Vec<f64> {
        let mut scratch = self.scratch();
        self.apply_with(x, dir, h2, &mut scratch)
    }

    /// [`GauntConvPlan::apply`] over caller scratch (crossover-dispatched
    /// aligned backend).
    pub fn apply_with(
        &self, x: &[f64], dir: [f64; 3], h2: &[f64],
        scratch: &mut GauntConvScratch,
    ) -> Vec<f64> {
        let mut out = vec![0.0; num_coeffs(self.l_out)];
        self.apply_full_into(x, dir, h2, ConvMethod::Auto, &mut out, scratch);
        out
    }

    /// The FULL edge convolution — alignment rotation, aligned-frame
    /// contraction, inverse rotation — over caller scratch, with zero
    /// steady-state allocations (the Wigner rotation blocks now live in
    /// the scratch; the per-degree fit caches are built on first use).
    ///
    /// `method` picks the aligned backend: `Direct` forces the
    /// single-column sweep, `Fft` the cached-spectrum FFT path, `Auto`
    /// the [`GAUNT_CONV_FFT_CROSSOVER`] dispatch.  This is the model
    /// layer's per-edge message primitive.
    pub fn apply_full_into(
        &self, x: &[f64], dir: [f64; 3], h2: &[f64], method: ConvMethod,
        out: &mut [f64], scratch: &mut GauntConvScratch,
    ) {
        let rot = align_to_z(dir);
        let n_in = num_coeffs(self.l_in);
        let n_out = num_coeffs(self.l_out);
        // take the rotation buffers out so the aligned `_into` calls can
        // borrow the rest of the scratch (swap, not allocation)
        let mut d_blk = std::mem::take(&mut scratch.d_blk);
        let mut x_rot = std::mem::take(&mut scratch.x_rot);
        let mut y_rot = std::mem::take(&mut scratch.y_rot);
        wigner_d_real_block_into(self.l_in, &rot, &mut d_blk,
                                 &mut scratch.wig);
        matvec_into(&d_blk, x, n_in, n_in, &mut x_rot);
        match method {
            ConvMethod::Direct => {
                self.apply_aligned_direct_into(&x_rot, h2, &mut y_rot, scratch)
            }
            ConvMethod::Fft => {
                self.apply_aligned_fft_into(&x_rot, h2, &mut y_rot, scratch)
            }
            ConvMethod::Auto => {
                self.apply_aligned_into(&x_rot, h2, &mut y_rot, scratch)
            }
        }
        wigner_d_real_block_into(self.l_out, &rot.transpose(), &mut d_blk,
                                 &mut scratch.wig);
        matvec_into(&d_blk, &y_rot, n_out, n_out, &mut out[..n_out]);
        scratch.d_blk = d_blk;
        scratch.x_rot = x_rot;
        scratch.y_rot = y_rot;
    }
}

/// Reference equivariant convolution: direct CG contraction with the full
/// SH filter (no alignment trick) — the "e3nn" way, used as the oracle.
pub fn conv_reference_cg(
    x: &[f64], l_in: usize, dir: [f64; 3], l_filter: usize, l_out: usize,
    h: &[f64], plan: &crate::tp::CgPlan,
) -> Vec<f64> {
    // h are per-(l1,l2,l3) path weights in EscnPlan path order; rebuild the
    // same ordering here.
    let ysh = real_sh_all_xyz(l_filter, dir);
    let mut out = vec![0.0; num_coeffs(l_out)];
    let mut idx = 0;
    for l1 in 0..=l_in {
        for l2 in 0..=l_filter {
            for l3 in l1.abs_diff(l2)..=(l1 + l2).min(l_out) {
                let w = h[idx];
                idx += 1;
                if w == 0.0 {
                    continue;
                }
                // contract the (l1,l2,l3) block of the full CG tensor
                let _ = plan;
                let c = cg_tensor_real(l_in, l_filter, l_out);
                let (n1, n2) = (num_coeffs(l_in), num_coeffs(l_filter));
                for m3 in -(l3 as i64)..=(l3 as i64) {
                    let k = lm_index(l3, m3);
                    let mut acc = 0.0;
                    for m1 in -(l1 as i64)..=(l1 as i64) {
                        for m2 in -(l2 as i64)..=(l2 as i64) {
                            acc += c[(k * n1 + lm_index(l1, m1)) * n2
                                + lm_index(l2, m2)]
                                * x[lm_index(l1, m1)]
                                * ysh[lm_index(l2, m2)];
                        }
                    }
                    out[k] += w * acc;
                }
            }
        }
    }
    out
}

/// Gaunt-parameterized reference conv (direct Gaunt contraction with the
/// full filter; oracle for GauntConvPlan).
pub fn conv_reference_gaunt(
    x: &[f64], l_in: usize, dir: [f64; 3], l_filter: usize, l_out: usize,
    h2: &[f64],
) -> Vec<f64> {
    let mut ysh = real_sh_all_xyz(l_filter, dir);
    crate::tp::irreps::Irreps::single(l_filter)
        .scale_paths_inplace(&mut ysh, h2);
    let plan = GauntPlan::new(l_in, l_filter, l_out,
                              crate::tp::ConvMethod::Direct);
    plan.apply(x, &ysh)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::so3::linalg::matvec;
    use crate::so3::rotation::wigner_d_real_block;
    use crate::util::prop::max_abs_diff;
    use crate::util::rng::Rng;

    #[test]
    fn aligned_filter_only_m0_couples() {
        // every CG entry with a non-zero m2 filter component must be
        // excluded by construction; check EscnPlan reproduces the full
        // contraction with an aligned filter.
        let (li, lf, lo) = (2usize, 2usize, 2usize);
        let plan = EscnPlan::new(li, lf, lo);
        let mut rng = Rng::new(0);
        let x = rng.normals(num_coeffs(li));
        let h: Vec<f64> = (0..plan.n_paths()).map(|_| rng.normal()).collect();
        let got = plan.apply_aligned(&x, &h);
        // reference: contraction with filter = sum of h-weighted Y(z)
        let want = conv_reference_cg(&x, li, [0.0, 0.0, 1.0], lf, lo, &h,
                                     &crate::tp::CgPlan::new(li, lf, lo));
        assert!(max_abs_diff(&got, &want) < 1e-9,
                "{}", max_abs_diff(&got, &want));
    }

    #[test]
    fn escn_full_matches_reference() {
        let (li, lf, lo) = (2usize, 2usize, 2usize);
        let plan = EscnPlan::new(li, lf, lo);
        let mut rng = Rng::new(1);
        for _ in 0..5 {
            let x = rng.normals(num_coeffs(li));
            let dir = [rng.normal(), rng.normal(), rng.normal()];
            let h: Vec<f64> = (0..plan.n_paths()).map(|_| rng.normal()).collect();
            let got = plan.apply(&x, dir, &h);
            let want = conv_reference_cg(&x, li, dir, lf, lo, &h,
                                         &crate::tp::CgPlan::new(li, lf, lo));
            assert!(max_abs_diff(&got, &want) < 1e-8,
                    "{}", max_abs_diff(&got, &want));
        }
    }

    #[test]
    fn gaunt_conv_matches_reference() {
        let (li, lf, lo) = (2usize, 2usize, 3usize);
        let plan = GauntConvPlan::new(li, lf, lo);
        let mut rng = Rng::new(2);
        for _ in 0..5 {
            let x = rng.normals(num_coeffs(li));
            let dir = [rng.normal(), rng.normal(), rng.normal()];
            let h2: Vec<f64> = (0..=lf).map(|_| rng.normal()).collect();
            let got = plan.apply(&x, dir, &h2);
            let want = conv_reference_gaunt(&x, li, dir, lf, lo, &h2);
            assert!(max_abs_diff(&got, &want) < 1e-8,
                    "{}", max_abs_diff(&got, &want));
        }
    }

    #[test]
    fn gaunt_conv_aligned_matches_plan() {
        // in the aligned frame the single-column convolution must equal the
        // generic GauntPlan applied to the aligned filter
        let (li, lf, lo) = (3usize, 2usize, 3usize);
        let plan = GauntConvPlan::new(li, lf, lo);
        let mut rng = Rng::new(3);
        let x = rng.normals(num_coeffs(li));
        let h2: Vec<f64> = (0..=lf).map(|_| rng.normal()).collect();
        let got = plan.apply_aligned(&x, &h2);
        let want = conv_reference_gaunt(&x, li, [0.0, 0.0, 1.0], lf, lo, &h2);
        assert!(max_abs_diff(&got, &want) < 1e-9);
    }

    #[test]
    fn gaunt_conv_fft_path_matches_direct_sweep() {
        // the cached-spectrum FFT path and the single-column sweep are
        // two evaluations of the same convolution
        for (li, lf, lo) in [(2usize, 2usize, 3usize), (3, 2, 3), (1, 3, 4)] {
            let plan = GauntConvPlan::new(li, lf, lo);
            let mut rng = Rng::new(5);
            let x = rng.normals(num_coeffs(li));
            let h2: Vec<f64> = (0..=lf).map(|_| rng.normal()).collect();
            let a = plan.apply_aligned_direct(&x, &h2);
            let b = plan.apply_aligned_fft(&x, &h2);
            assert!(max_abs_diff(&a, &b) < 1e-9,
                    "({li},{lf},{lo}): {}", max_abs_diff(&a, &b));
            let want = conv_reference_gaunt(&x, li, [0.0, 0.0, 1.0], lf, lo, &h2);
            assert!(max_abs_diff(&b, &want) < 1e-8);
        }
    }

    #[test]
    fn apply_full_into_matches_reference_for_both_methods() {
        let (li, lf, lo) = (2usize, 2usize, 2usize);
        let plan = GauntConvPlan::new(li, lf, lo);
        let mut rng = Rng::new(6);
        let mut scratch = plan.scratch();
        for _ in 0..4 {
            let x = rng.normals(num_coeffs(li));
            let dir = [rng.normal(), rng.normal(), rng.normal()];
            let h2: Vec<f64> = (0..=lf).map(|_| rng.normal()).collect();
            let want = conv_reference_gaunt(&x, li, dir, lf, lo, &h2);
            let mut out = vec![0.0; num_coeffs(lo)];
            for method in [ConvMethod::Direct, ConvMethod::Fft,
                           ConvMethod::Auto] {
                plan.apply_full_into(&x, dir, &h2, method, &mut out,
                                     &mut scratch);
                assert!(max_abs_diff(&out, &want) < 1e-8,
                        "{method:?}: {}", max_abs_diff(&out, &want));
            }
            // and the Vec-returning wrapper stays pinned to the same result
            let via_with = plan.apply_with(&x, dir, &h2, &mut scratch);
            assert!(max_abs_diff(&via_with, &want) < 1e-8);
        }
    }

    #[test]
    fn escn_vjp_is_the_exact_transpose() {
        // <g, M x> == <M^T g, x>: the adjoint identity that makes
        // vjp_into exact for the linear edge convolution
        let (li, lf, lo) = (2usize, 2usize, 3usize);
        let plan = EscnPlan::new(li, lf, lo);
        let mut rng = Rng::new(7);
        let dir = rng.unit3();
        let h: Vec<f64> = (0..plan.n_paths()).map(|_| rng.normal()).collect();
        let (n_in, n_out) = (num_coeffs(li), num_coeffs(lo));
        let mut scratch = plan.scratch();
        for _ in 0..4 {
            let x = rng.normals(n_in);
            let g = rng.normals(n_out);
            let mut y = vec![0.0; n_out];
            plan.apply_into(&x, dir, &h, &mut y, &mut scratch);
            let mut gx = vec![0.0; n_in];
            plan.vjp_into(dir, &h, &g, &mut gx, &mut scratch);
            let lhs: f64 = g.iter().zip(&y).map(|(a, b)| a * b).sum();
            let rhs: f64 = gx.iter().zip(&x).map(|(a, b)| a * b).sum();
            assert!((lhs - rhs).abs() < 1e-9 * (1.0 + lhs.abs()),
                    "{lhs} vs {rhs}");
        }
    }

    #[test]
    fn escn_equivariance() {
        let (li, lf, lo) = (1usize, 1usize, 2usize);
        let plan = EscnPlan::new(li, lf, lo);
        let mut rng = Rng::new(4);
        let rot = Rot3::random(&mut rng);
        let x = rng.normals(num_coeffs(li));
        let dir = rng.unit3();
        let h: Vec<f64> = (0..plan.n_paths()).map(|_| rng.normal()).collect();
        let d_in = wigner_d_real_block(li, &rot);
        let d_out = wigner_d_real_block(lo, &rot);
        let n_in = num_coeffs(li);
        let n_out = num_coeffs(lo);
        let a = plan.apply(&matvec(&d_in, &x, n_in, n_in), rot.apply(dir), &h);
        let b = matvec(&d_out, &plan.apply(&x, dir, &h), n_out, n_out);
        assert!(max_abs_diff(&a, &b) < 1e-8);
    }
}
