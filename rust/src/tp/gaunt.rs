//! The Gaunt Tensor Product fast path (paper Section 3.2): O(L^3).
//!
//! Pipeline per pair of inputs:
//!   1. sh2f  — per-|v| panel contraction (exploits the m = +-v sparsity),
//!   2. conv  — 2D convolution of the coefficient grids (direct for small
//!              L, planned Hermitian FFT for large — see
//!              [`crate::fourier::plan::ConvPlan`]),
//!   3. f2sh  — row-major per-|v| back-projection onto SH coefficients
//!              ([`crate::fourier::tables::f2sh_contract`]).
//!
//! A [`GauntPlan`] precomputes all tables for fixed (L1, L2, L3); the
//! fused [`GauntPlan::apply_into`] runs the whole pipeline over a
//! caller-owned [`GauntScratch`] with zero allocations, so batched
//! applies (and the engine's sharded workers, each holding one scratch)
//! have no steady-state allocation at all.

use crate::fourier::complex::C64;
use crate::fourier::conv::conv2d_direct_into;
use crate::fourier::plan::{ConvPlan, ConvScratch};
use crate::fourier::tables::{
    f2sh_contract, sh2f_panels, F2shPanelsT, Sh2fPanels, SQRT2_OVER_2,
};
use crate::tp::irreps::Irreps;
use crate::{lm_index, num_coeffs};

/// Which convolution backend the plan uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ConvMethod {
    Direct,
    Fft,
    /// Direct below the crossover degree, FFT above (the shipped default).
    Auto,
}

/// Degree sum at and above which `ConvMethod::Auto` switches from the
/// direct O(L^4) convolution to the planned Hermitian FFT path.
///
/// Re-tuned for the planned path: the legacy allocating `conv2d_fft`
/// crossed over around l1 + l2 = 12; the planned path does ~2.5 m
/// instead of 6 m length-m transforms per pair and allocates nothing,
/// moving the modeled flop crossover to l1 + l2 ~ 10 (direct:
/// ~6 (2L+1)^4 flops; planned FFT: ~17.5 m^2 log2 m with m =
/// 2^ceil(log2(2L+1)), L = l1 + l2).  `table2_speed_memory` measures and
/// prints the actual per-L ratios so this constant can be re-pinned on
/// real hardware.
pub const AUTO_FFT_CROSSOVER: usize = 10;

/// Caller-owned scratch for the fused Gaunt pipeline: one per worker
/// thread; all buffers are sized at plan granularity and never resized,
/// so steady-state applies allocate nothing.
pub struct GauntScratch {
    /// sh2f staging W[l, s] (max of the two operand sizes)
    w: Vec<C64>,
    /// operand Fourier grids
    g1: Vec<C64>,
    g2: Vec<C64>,
    /// product grid (2(l1+l2)+1)^2
    out_grid: Vec<C64>,
    /// planned-convolution workspace
    conv: ConvScratch,
}

/// Precomputed plan for x1 (deg <= L1) (x) x2 (deg <= L2) -> deg <= L3.
pub struct GauntPlan {
    pub l1: usize,
    pub l2: usize,
    pub l3: usize,
    pub method: ConvMethod,
    p1: Sh2fPanels,
    p2: Sh2fPanels,
    t3t: F2shPanelsT,
    conv: ConvPlan,
    n_grid: usize, // product grid half-width = l1 + l2
}

impl GauntPlan {
    pub fn new(l1: usize, l2: usize, l3: usize, method: ConvMethod) -> Self {
        let n_grid = l1 + l2;
        GauntPlan {
            l1,
            l2,
            l3,
            method,
            p1: sh2f_panels(l1),
            p2: sh2f_panels(l2),
            t3t: F2shPanelsT::build(l3, n_grid),
            conv: ConvPlan::new(2 * l1 + 1, 2 * l2 + 1),
            n_grid,
        }
    }

    /// Fresh scratch sized for this plan (one per worker thread).  A
    /// plan whose method resolves to the direct convolution never
    /// touches the FFT workspace, so it is skipped entirely (the plan's
    /// method is fixed at construction).
    pub fn scratch(&self) -> GauntScratch {
        let n1 = 2 * self.l1 + 1;
        let n2 = 2 * self.l2 + 1;
        let nu3 = 2 * self.n_grid + 1;
        let nw = (self.l1 + 1).max(self.l2 + 1);
        GauntScratch {
            w: vec![C64::default(); nw * nw],
            g1: vec![C64::default(); n1 * n1],
            g2: vec![C64::default(); n2 * n2],
            out_grid: vec![C64::default(); nu3 * nu3],
            conv: if self.uses_fft() {
                self.conv.scratch()
            } else {
                ConvScratch::empty()
            },
        }
    }

    /// SH coefficients -> complex Fourier grid (2L+1)^2 (row-major
    /// [u][v]) into caller buffers: `grid` is the (2L+1)^2 output, `w`
    /// the (L+1)^2 staging area.  Allocation-free.
    pub fn sh2f_into(
        panels: &Sh2fPanels, x: &[f64], grid: &mut [C64], w: &mut [C64],
    ) {
        let l_max = panels.l_max;
        let nu = 2 * l_max + 1;
        let nl = l_max + 1;
        debug_assert_eq!(x.len(), num_coeffs(l_max));
        debug_assert_eq!(grid.len(), nu * nu);
        debug_assert!(w.len() >= nl * nl);
        // W[l, s]
        let w = &mut w[..nl * nl];
        w.fill(C64::default());
        for l in 0..=l_max {
            w[l * nl] = C64::real(x[lm_index(l, 0)]);
            for s in 1..=l {
                w[l * nl + s] = C64::new(
                    SQRT2_OVER_2 * x[lm_index(l, s as i64)],
                    -SQRT2_OVER_2 * x[lm_index(l, -(s as i64))],
                );
            }
        }
        grid.fill(C64::default());
        for s in 0..=l_max {
            let p = &panels.panels[s];
            for u in 0..nu {
                let row = &p[u * nl..(u + 1) * nl];
                let mut accp = C64::default();
                let mut accm = C64::default();
                for l in s..=l_max {
                    let pv = row[l];
                    if pv.norm_sqr() == 0.0 {
                        continue;
                    }
                    let wv = w[l * nl + s];
                    accp += pv * wv;
                    accm += pv * wv.conj();
                }
                grid[u * nu + (l_max + s)] = accp;
                if s > 0 {
                    grid[u * nu + (l_max - s)] = accm;
                }
            }
        }
    }

    /// SH coefficients -> complex Fourier grid (allocating wrapper around
    /// [`GauntPlan::sh2f_into`]).
    pub fn sh2f(panels: &Sh2fPanels, x: &[f64]) -> Vec<C64> {
        let l_max = panels.l_max;
        let nu = 2 * l_max + 1;
        let nl = l_max + 1;
        let mut grid = vec![C64::default(); nu * nu];
        let mut w = vec![C64::default(); nl * nl];
        Self::sh2f_into(panels, x, &mut grid, &mut w);
        grid
    }

    /// Product grid (2N+1)^2 -> SH coefficients (deg <= L3), into a
    /// caller buffer of `num_coeffs(L3)`.  Allocation-free row-major
    /// traversal over the transposed panels.
    pub fn f2sh_into(&self, grid: &[C64], out: &mut [f64]) {
        f2sh_contract(&self.t3t, grid, out);
    }

    /// Product grid (2N+1)^2 -> SH coefficients (deg <= L3).
    pub fn f2sh(&self, grid: &[C64]) -> Vec<f64> {
        let mut x = vec![0.0; num_coeffs(self.l3)];
        self.f2sh_into(grid, &mut x);
        x
    }

    /// Whether this plan's method resolves to the FFT backend.
    pub fn uses_fft(&self) -> bool {
        match self.method {
            ConvMethod::Direct => false,
            ConvMethod::Fft => true,
            ConvMethod::Auto => self.l1 + self.l2 >= AUTO_FFT_CROSSOVER,
        }
    }

    fn convolve_into(
        &self, a: &[C64], b: &[C64], out: &mut [C64], conv: &mut ConvScratch,
    ) {
        let n1 = 2 * self.l1 + 1;
        let n2 = 2 * self.l2 + 1;
        if self.uses_fft() {
            // sh2f grids of real SH coefficients are Hermitian:
            // g(-u,-v) = conj(g(u,v))
            self.conv.conv_hermitian_into(a, b, out, conv);
        } else {
            conv2d_direct_into(a, n1, b, n2, out);
        }
    }

    /// The fused Gaunt Tensor Product of one pair of features, written
    /// into `out` (`num_coeffs(L3)`), with every intermediate living in
    /// `scratch`: zero allocations in steady state.
    pub fn apply_into(
        &self, x1: &[f64], x2: &[f64], out: &mut [f64],
        scratch: &mut GauntScratch,
    ) {
        Self::sh2f_into(&self.p1, x1, &mut scratch.g1, &mut scratch.w);
        Self::sh2f_into(&self.p2, x2, &mut scratch.g2, &mut scratch.w);
        self.convolve_into(
            &scratch.g1,
            &scratch.g2,
            &mut scratch.out_grid,
            &mut scratch.conv,
        );
        self.f2sh_into(&scratch.out_grid, out);
    }

    /// The Gaunt Tensor Product of one pair of features.
    pub fn apply(&self, x1: &[f64], x2: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; num_coeffs(self.l3)];
        let mut scratch = self.scratch();
        self.apply_into(x1, x2, &mut out, &mut scratch);
        out
    }

    /// Weighted variant (paper Sec. 3.3 reparameterization): per-degree
    /// weights w1[l1], w2[l2], w3[l3] multiply inputs/outputs.  The
    /// per-degree reweighting is the single-channel case of
    /// [`Irreps::scale_paths_inplace`] — the one shared scaling helper
    /// (the model's per-path residual mixes are the same call at
    /// `mul > 1`).
    pub fn apply_weighted(
        &self,
        x1: &[f64],
        w1: &[f64],
        x2: &[f64],
        w2: &[f64],
        w3: &[f64],
    ) -> Vec<f64> {
        let mut s1 = x1.to_vec();
        Irreps::single(self.l1).scale_paths_inplace(&mut s1, w1);
        let mut s2 = x2.to_vec();
        Irreps::single(self.l2).scale_paths_inplace(&mut s2, w2);
        let mut out = self.apply(&s1, &s2);
        Irreps::single(self.l3).scale_paths_inplace(&mut out, w3);
        out
    }

    /// Batched apply (rows of x1/x2 are independent features).  One
    /// scratch is allocated up front and reused for every row: the
    /// steady-state per-row cost is allocation-free.
    pub fn apply_batch(&self, x1: &[f64], x2: &[f64], rows: usize) -> Vec<f64> {
        let n1 = num_coeffs(self.l1);
        let n2 = num_coeffs(self.l2);
        let n3 = num_coeffs(self.l3);
        let mut out = vec![0.0; rows * n3];
        let mut scratch = self.scratch();
        for r in 0..rows {
            let (x1r, x2r) =
                (&x1[r * n1..(r + 1) * n1], &x2[r * n2..(r + 1) * n2]);
            self.apply_into(x1r, x2r, &mut out[r * n3..(r + 1) * n3],
                            &mut scratch);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::so3::gaunt::gaunt_tensor_real;
    use crate::so3::rotation::{wigner_d_real_block, Rot3};
    use crate::so3::linalg::matvec;
    use crate::util::prop::{check, max_abs_diff, PropConfig};
    use crate::util::rng::Rng;

    fn direct_contraction(
        x1: &[f64], l1: usize, x2: &[f64], l2: usize, l3: usize,
    ) -> Vec<f64> {
        let g = gaunt_tensor_real(l1, l2, l3);
        let (n1, n2, n3) = (num_coeffs(l1), num_coeffs(l2), num_coeffs(l3));
        let mut out = vec![0.0; n3];
        for k in 0..n3 {
            for i in 0..n1 {
                for j in 0..n2 {
                    out[k] += g[(k * n1 + i) * n2 + j] * x1[i] * x2[j];
                }
            }
        }
        out
    }

    #[test]
    fn matches_direct_contraction() {
        let mut rng = Rng::new(0);
        for (l1, l2, l3) in [(0usize, 0usize, 0usize), (1, 1, 2), (2, 2, 2),
                             (3, 2, 4), (2, 3, 1), (4, 4, 4)] {
            let x1 = rng.normals(num_coeffs(l1));
            let x2 = rng.normals(num_coeffs(l2));
            for method in [ConvMethod::Direct, ConvMethod::Fft] {
                let plan = GauntPlan::new(l1, l2, l3, method);
                let got = plan.apply(&x1, &x2);
                let want = direct_contraction(&x1, l1, &x2, l2, l3);
                assert!(
                    max_abs_diff(&got, &want) < 1e-9,
                    "({l1},{l2},{l3}) {method:?}: {}",
                    max_abs_diff(&got, &want)
                );
            }
        }
    }

    #[test]
    fn multiplication_by_constant_is_identity() {
        let mut rng = Rng::new(1);
        let l = 3;
        let x = rng.normals(num_coeffs(l));
        let one = vec![(4.0 * std::f64::consts::PI).sqrt()];
        let plan = GauntPlan::new(l, 0, l, ConvMethod::Direct);
        let out = plan.apply(&x, &one);
        assert!(max_abs_diff(&out, &x) < 1e-10);
    }

    #[test]
    fn equivariance_property() {
        check("gaunt-tp-equivariance", PropConfig { cases: 16, seed: 2 },
              |rng, _| {
            let l = 2usize;
            let rot = Rot3::random(rng);
            let d = wigner_d_real_block(l, &rot);
            let d_out = wigner_d_real_block(2 * l, &rot);
            let x1 = rng.normals(num_coeffs(l));
            let x2 = rng.normals(num_coeffs(l));
            let n = num_coeffs(l);
            let plan = GauntPlan::new(l, l, 2 * l, ConvMethod::Auto);
            let a = plan.apply(
                &matvec(&d, &x1, n, n),
                &matvec(&d, &x2, n, n),
            );
            let b0 = plan.apply(&x1, &x2);
            let nn = num_coeffs(2 * l);
            let b = matvec(&d_out, &b0, nn, nn);
            if max_abs_diff(&a, &b) < 1e-8 {
                Ok(())
            } else {
                Err(format!("equivariance violated: {}", max_abs_diff(&a, &b)))
            }
        });
    }

    #[test]
    fn bilinearity_property() {
        check("gaunt-tp-bilinear", PropConfig { cases: 32, seed: 3 },
              |rng, _| {
            let plan = GauntPlan::new(2, 2, 3, ConvMethod::Direct);
            let n = num_coeffs(2);
            let x1: Vec<f64> = rng.normals(n);
            let x1b: Vec<f64> = rng.normals(n);
            let x2: Vec<f64> = rng.normals(n);
            let a = rng.uniform(-2.0, 2.0);
            let lhs_in: Vec<f64> =
                x1.iter().zip(&x1b).map(|(p, q)| a * p + q).collect();
            let lhs = plan.apply(&lhs_in, &x2);
            let r1 = plan.apply(&x1, &x2);
            let r2 = plan.apply(&x1b, &x2);
            let rhs: Vec<f64> = r1.iter().zip(&r2).map(|(p, q)| a * p + q).collect();
            if max_abs_diff(&lhs, &rhs) < 1e-9 {
                Ok(())
            } else {
                Err("not bilinear".into())
            }
        });
    }

    #[test]
    fn pointwise_product_semantics() {
        use crate::so3::sh::eval_sh_series;
        let mut rng = Rng::new(4);
        let l = 2;
        let x1 = rng.normals(num_coeffs(l));
        let x2 = rng.normals(num_coeffs(l));
        let plan = GauntPlan::new(l, l, 2 * l, ConvMethod::Fft);
        let x3 = plan.apply(&x1, &x2);
        for _ in 0..20 {
            let theta = rng.uniform(0.1, 3.0);
            let phi = rng.uniform(0.0, 6.28);
            let f1 = eval_sh_series(&x1, l, theta, phi);
            let f2 = eval_sh_series(&x2, l, theta, phi);
            let f3 = eval_sh_series(&x3, 2 * l, theta, phi);
            assert!((f3 - f1 * f2).abs() < 1e-9);
        }
    }

    #[test]
    fn weighted_variant() {
        let mut rng = Rng::new(5);
        let l = 2;
        let x1 = rng.normals(num_coeffs(l));
        let x2 = rng.normals(num_coeffs(l));
        let w1 = rng.normals(l + 1);
        let w2 = rng.normals(l + 1);
        let w3 = rng.normals(2 * l + 1);
        let plan = GauntPlan::new(l, l, 2 * l, ConvMethod::Direct);
        let got = plan.apply_weighted(&x1, &w1, &x2, &w2, &w3);
        // reference: weight the direct contraction per (l1,l2,l3) block
        let g = gaunt_tensor_real(l, l, 2 * l);
        let (n1, n2, n3) = (num_coeffs(l), num_coeffs(l), num_coeffs(2 * l));
        let mut want = vec![0.0; n3];
        for l3 in 0..=(2 * l) {
            for m3 in -(l3 as i64)..=(l3 as i64) {
                let k = lm_index(l3, m3);
                for l1 in 0..=l {
                    for m1 in -(l1 as i64)..=(l1 as i64) {
                        let i = lm_index(l1, m1);
                        for l2 in 0..=l {
                            for m2 in -(l2 as i64)..=(l2 as i64) {
                                let j = lm_index(l2, m2);
                                want[k] += w1[l1] * w2[l2] * w3[l3]
                                    * g[(k * n1 + i) * n2 + j]
                                    * x1[i] * x2[j];
                            }
                        }
                    }
                }
            }
        }
        assert!(max_abs_diff(&got, &want) < 1e-9);
    }

    #[test]
    fn batch_matches_single() {
        let mut rng = Rng::new(6);
        let plan = GauntPlan::new(2, 2, 2, ConvMethod::Auto);
        let n = num_coeffs(2);
        let rows = 5;
        let x1 = rng.normals(rows * n);
        let x2 = rng.normals(rows * n);
        let batch = plan.apply_batch(&x1, &x2, rows);
        for r in 0..rows {
            let single = plan.apply(&x1[r * n..(r + 1) * n], &x2[r * n..(r + 1) * n]);
            assert!(max_abs_diff(&batch[r * n..(r + 1) * n], &single) < 1e-12);
        }
    }

    #[test]
    fn apply_into_matches_apply_and_scratch_reuse_is_exact() {
        let mut rng = Rng::new(8);
        let plan = GauntPlan::new(3, 2, 4, ConvMethod::Fft);
        let x1 = rng.normals(num_coeffs(3));
        let x2 = rng.normals(num_coeffs(2));
        let want = plan.apply(&x1, &x2);
        let mut scratch = plan.scratch();
        let mut out = vec![0.0; num_coeffs(4)];
        // dirty the scratch with one unrelated pair, then reuse it
        let y1 = rng.normals(num_coeffs(3));
        let y2 = rng.normals(num_coeffs(2));
        plan.apply_into(&y1, &y2, &mut out, &mut scratch);
        plan.apply_into(&x1, &x2, &mut out, &mut scratch);
        assert!(max_abs_diff(&out, &want) == 0.0, "scratch state leaked");
    }

    #[test]
    fn auto_crossover_resolution() {
        assert!(!GauntPlan::new(2, 2, 2, ConvMethod::Auto).uses_fft());
        assert!(!GauntPlan::new(4, 4, 4, ConvMethod::Auto).uses_fft());
        assert!(GauntPlan::new(5, 5, 5, ConvMethod::Auto).uses_fft());
        assert!(GauntPlan::new(6, 4, 6, ConvMethod::Auto).uses_fft());
        assert!(GauntPlan::new(3, 3, 3, ConvMethod::Fft).uses_fft());
        assert!(!GauntPlan::new(8, 8, 8, ConvMethod::Direct).uses_fft());
    }

    #[test]
    fn fft_and_direct_agree_above_crossover() {
        let mut rng = Rng::new(9);
        let l = 6usize;
        let x1 = rng.normals(num_coeffs(l));
        let x2 = rng.normals(num_coeffs(l));
        let auto = GauntPlan::new(l, l, l, ConvMethod::Auto);
        assert!(auto.uses_fft());
        let got = auto.apply(&x1, &x2);
        let want = GauntPlan::new(l, l, l, ConvMethod::Direct).apply(&x1, &x2);
        assert!(max_abs_diff(&got, &want) < 1e-8,
                "{}", max_abs_diff(&got, &want));
    }

    #[test]
    fn truncation_matches_projection() {
        let mut rng = Rng::new(7);
        let x1 = rng.normals(num_coeffs(3));
        let x2 = rng.normals(num_coeffs(2));
        let full = GauntPlan::new(3, 2, 5, ConvMethod::Fft).apply(&x1, &x2);
        let trunc = GauntPlan::new(3, 2, 2, ConvMethod::Fft).apply(&x1, &x2);
        assert!(max_abs_diff(&trunc, &full[..num_coeffs(2)]) < 1e-10);
    }
}
