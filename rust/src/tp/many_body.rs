//! Equivariant Many-body Interactions: nu-fold tensor products
//! (paper Sec. 3.3 + Appendix C).
//!
//! Three evaluation strategies, matching the paper's comparison:
//!
//! * [`many_body_cg_fold`] — e3nn-style left fold of pairwise CG products
//!   with growing intermediate degree (the slow baseline),
//! * [`MaceStylePlan`] — MACE-style: precompute the *composed* coupling
//!   tensor C[k, i1..i_nu] once and contract (fast apply, memory grows as
//!   O(n^nu) — the "trades space for speed" row of Table 2),
//! * [`many_body_gaunt`] — the paper's method: convert once, chain 2D
//!   convolutions in the Fourier domain (sequential or divide-and-conquer
//!   order), project back once.

use crate::fourier::complex::C64;
use crate::fourier::conv::conv2d_direct;
use crate::so3::gaunt::gaunt_tensor_real;
use crate::tp::cg::CgPlan;
use crate::tp::gaunt::GauntPlan;
use crate::fourier::tables::{f2sh_panels, sh2f_panels};
use crate::num_coeffs;

/// e3nn-style fold: ((x1 (x) x2) (x) x3) ... with CG couplings, keeping all
/// intermediate degrees up to `cap` (= min(sum of degrees, l_cap)).
pub fn many_body_cg_fold(xs: &[Vec<f64>], l: usize, l_out: usize,
                         l_cap: usize) -> Vec<f64> {
    assert!(!xs.is_empty());
    let mut acc = xs[0].clone();
    let mut l_acc = l;
    for x in &xs[1..] {
        let l_next = (l_acc + l).min(l_cap);
        let plan = CgPlan::new(l_acc, l, l_next);
        acc = plan.apply_sparse(&acc, x);
        l_acc = l_next;
    }
    acc.truncate(num_coeffs(l_out));
    acc
}

/// Gaunt-parameterized fold (same shape, Gaunt couplings) — the oracle for
/// the Fourier-domain strategies.
pub fn many_body_gaunt_fold(xs: &[Vec<f64>], l: usize, l_out: usize) -> Vec<f64> {
    assert!(!xs.is_empty());
    let mut acc = xs[0].clone();
    let mut l_acc = l;
    for x in &xs[1..] {
        let plan = GauntPlan::new(l_acc, l, l_acc + l,
                                  crate::tp::ConvMethod::Auto);
        acc = plan.apply(&acc, x);
        l_acc += l;
    }
    acc.truncate(num_coeffs(l_out));
    acc
}

/// The paper's many-body path: sh2f each operand once, convolve the grids
/// (sequential chain or divide-and-conquer tree), f2sh once at the end.
pub fn many_body_gaunt(xs: &[Vec<f64>], l: usize, l_out: usize,
                       divide_and_conquer: bool) -> Vec<f64> {
    assert!(!xs.is_empty());
    let nu = xs.len();
    let panels = sh2f_panels(l);
    let mut grids: Vec<(Vec<C64>, usize)> = xs
        .iter()
        .map(|x| (GauntPlan::sh2f(&panels, x), 2 * l + 1))
        .collect();
    let merged = if divide_and_conquer {
        // pairwise tree reduction
        while grids.len() > 1 {
            let mut next = Vec::with_capacity(grids.len().div_ceil(2));
            let mut it = grids.into_iter();
            while let Some((a, na)) = it.next() {
                match it.next() {
                    Some((b, nb)) => {
                        let out = conv2d_direct(&a, na, &b, nb);
                        next.push((out, na + nb - 1));
                    }
                    None => next.push((a, na)),
                }
            }
            grids = next;
        }
        grids.pop().unwrap()
    } else {
        let mut it = grids.into_iter();
        let (mut acc, mut n) = it.next().unwrap();
        for (b, nb) in it {
            acc = conv2d_direct(&acc, n, &b, nb);
            n = n + nb - 1;
        }
        (acc, n)
    };
    let (grid, n_side) = merged;
    let n_grid = (n_side - 1) / 2;
    debug_assert_eq!(n_grid, nu * l);
    let t3 = f2sh_panels(l_out, n_grid);
    f2sh_apply_panels(&t3, &grid, l_out, n_grid)
}

fn f2sh_apply_panels(
    t3: &crate::fourier::tables::F2shPanels, grid: &[C64], l_out: usize,
    n: usize,
) -> Vec<f64> {
    let nu = 2 * n + 1;
    let mut x = vec![0.0; num_coeffs(l_out)];
    let pi = std::f64::consts::PI;
    let s2pi = std::f64::consts::SQRT_2 * pi;
    for s in 0..=l_out {
        let t = &t3.panels[s];
        for l in s..=l_out {
            let trow = &t[l * nu..(l + 1) * nu];
            if s == 0 {
                let mut acc = 0.0;
                for u in 0..nu {
                    let g = grid[u * nu + n];
                    acc += trow[u].re * g.re - trow[u].im * g.im;
                }
                x[crate::lm_index(l, 0)] = 2.0 * pi * acc;
            } else {
                let mut accp = 0.0;
                let mut accm = 0.0;
                for u in 0..nu {
                    let gp = grid[u * nu + n + s];
                    let gm = grid[u * nu + n - s];
                    let sp = gp + gm;
                    let sm = gp - gm;
                    accp += trow[u].re * sp.re - trow[u].im * sp.im;
                    accm += -(trow[u].im * sm.re + trow[u].re * sm.im);
                }
                x[crate::lm_index(l, s as i64)] = s2pi * accp;
                x[crate::lm_index(l, -(s as i64))] = s2pi * accm;
            }
        }
    }
    x
}

/// MACE-style precomputed composite coupling: C[k, i1, ..., i_nu] built by
/// composing pairwise Gaunt tensors once; apply is a dense contraction.
/// Memory O(n_out * n^nu) — the space-for-speed trade of Table 2.
pub struct MaceStylePlan {
    pub nu: usize,
    pub l: usize,
    pub l_out: usize,
    n_in: usize,
    n_out: usize,
    /// tensor[k * n^nu + multi-index(i1..i_nu)]
    tensor: Vec<f64>,
}

impl MaceStylePlan {
    pub fn new(nu: usize, l: usize, l_out: usize) -> Self {
        assert!(nu >= 2);
        let n_in = num_coeffs(l);
        // start with pairwise tensor to degree 2l, then absorb one operand
        // at a time (intermediate degree grows exactly, no truncation until
        // the last step).
        let mut l_acc = 2 * l;
        let mut t = gaunt_tensor_real(l, l, l_acc); // [k, i, j]
        let mut rank = 2usize;
        while rank < nu {
            let l_next = if rank + 1 == nu { l_out } else { l_acc + l };
            let g = gaunt_tensor_real(l_acc, l, l_next); // [k2, p, i_new]
            let n_acc = num_coeffs(l_acc);
            let n_next = num_coeffs(l_next);
            let width = n_in.pow(rank as u32);
            let mut t2 = vec![0.0; n_next * width * n_in];
            for k2 in 0..n_next {
                for p in 0..n_acc {
                    for inew in 0..n_in {
                        let gv = g[(k2 * n_acc + p) * n_in + inew];
                        if gv == 0.0 {
                            continue;
                        }
                        let src = &t[p * width..(p + 1) * width];
                        let dst = &mut t2
                            [(k2 * width * n_in)..((k2 + 1) * width * n_in)];
                        for (w, sv) in src.iter().enumerate() {
                            if *sv != 0.0 {
                                dst[w * n_in + inew] += gv * sv;
                            }
                        }
                    }
                }
            }
            t = t2;
            l_acc = l_next;
            rank += 1;
        }
        // if nu == 2, truncate the pairwise tensor to l_out
        let (tensor, l_final) = if nu == 2 {
            let n_out = num_coeffs(l_out);
            (t[..n_out * n_in * n_in].to_vec(), l_out)
        } else {
            (t, l_acc)
        };
        debug_assert_eq!(l_final, l_out);
        MaceStylePlan {
            nu,
            l,
            l_out,
            n_in,
            n_out: num_coeffs(l_out),
            tensor,
        }
    }

    pub fn memory_bytes(&self) -> usize {
        self.tensor.len() * std::mem::size_of::<f64>()
    }

    /// Contract against nu copies (here: the same feature, as in MACE's
    /// B-features) — specialized for nu in 2..=4.
    pub fn apply_self(&self, x: &[f64]) -> Vec<f64> {
        let n = self.n_in;
        let mut out = vec![0.0; self.n_out];
        match self.nu {
            2 => {
                for k in 0..self.n_out {
                    let blk = &self.tensor[k * n * n..(k + 1) * n * n];
                    let mut acc = 0.0;
                    for i in 0..n {
                        if x[i] == 0.0 {
                            continue;
                        }
                        let row = &blk[i * n..(i + 1) * n];
                        let mut s = 0.0;
                        for j in 0..n {
                            s += row[j] * x[j];
                        }
                        acc += x[i] * s;
                    }
                    out[k] = acc;
                }
            }
            3 => {
                let w = n * n * n;
                for k in 0..self.n_out {
                    let blk = &self.tensor[k * w..(k + 1) * w];
                    let mut acc = 0.0;
                    for i in 0..n {
                        let xi = x[i];
                        if xi == 0.0 {
                            continue;
                        }
                        for j in 0..n {
                            let xij = xi * x[j];
                            if xij == 0.0 {
                                continue;
                            }
                            let row = &blk[(i * n + j) * n..(i * n + j + 1) * n];
                            let mut s = 0.0;
                            for p in 0..n {
                                s += row[p] * x[p];
                            }
                            acc += xij * s;
                        }
                    }
                    out[k] = acc;
                }
            }
            4 => {
                let w = n * n * n * n;
                for k in 0..self.n_out {
                    let blk = &self.tensor[k * w..(k + 1) * w];
                    let mut acc = 0.0;
                    for i in 0..n {
                        let xi = x[i];
                        if xi == 0.0 {
                            continue;
                        }
                        for j in 0..n {
                            let xij = xi * x[j];
                            for p in 0..n {
                                let xijp = xij * x[p];
                                if xijp == 0.0 {
                                    continue;
                                }
                                let row = &blk[((i * n + j) * n + p) * n..];
                                let mut s = 0.0;
                                for q in 0..n {
                                    s += row[q] * x[q];
                                }
                                acc += xijp * s;
                            }
                        }
                    }
                    out[k] = acc;
                }
            }
            _ => panic!("MaceStylePlan supports nu in 2..=4"),
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::max_abs_diff;
    use crate::util::rng::Rng;

    #[test]
    fn gaunt_grid_chain_matches_fold() {
        let mut rng = Rng::new(0);
        let l = 1usize;
        for nu in 2..=4usize {
            let xs: Vec<Vec<f64>> =
                (0..nu).map(|_| rng.normals(num_coeffs(l))).collect();
            let want = many_body_gaunt_fold(&xs, l, 2);
            let seq = many_body_gaunt(&xs, l, 2, false);
            let dc = many_body_gaunt(&xs, l, 2, true);
            assert!(max_abs_diff(&want, &seq) < 1e-9, "seq nu={nu}");
            assert!(max_abs_diff(&want, &dc) < 1e-9, "dc nu={nu}");
        }
    }

    #[test]
    fn dc_equals_sequential_l2() {
        let mut rng = Rng::new(1);
        let l = 2usize;
        let xs: Vec<Vec<f64>> =
            (0..3).map(|_| rng.normals(num_coeffs(l))).collect();
        let seq = many_body_gaunt(&xs, l, 2, false);
        let dc = many_body_gaunt(&xs, l, 2, true);
        assert!(max_abs_diff(&seq, &dc) < 1e-9);
    }

    #[test]
    fn mace_style_matches_gaunt_fold() {
        let mut rng = Rng::new(2);
        for (nu, l) in [(2usize, 2usize), (3, 1), (3, 2), (4, 1)] {
            let x = rng.normals(num_coeffs(l));
            let xs: Vec<Vec<f64>> = (0..nu).map(|_| x.clone()).collect();
            let want = many_body_gaunt_fold(&xs, l, l);
            let plan = MaceStylePlan::new(nu, l, l);
            let got = plan.apply_self(&x);
            assert!(max_abs_diff(&got, &want) < 1e-8,
                    "nu={nu} l={l}: {}", max_abs_diff(&got, &want));
        }
    }

    #[test]
    fn mace_style_memory_grows() {
        let m2 = MaceStylePlan::new(2, 1, 2).memory_bytes();
        let m3 = MaceStylePlan::new(3, 1, 2).memory_bytes();
        assert!(m3 > 2 * m2);
    }

    #[test]
    fn cg_fold_differs_from_gaunt_fold() {
        // CG keeps odd-parity paths; the two many-body features disagree
        let mut rng = Rng::new(3);
        let l = 1usize;
        let xs: Vec<Vec<f64>> =
            (0..3).map(|_| rng.normals(num_coeffs(l))).collect();
        let cg = many_body_cg_fold(&xs, l, 2, 3);
        let ga = many_body_gaunt_fold(&xs, l, 2);
        assert!(max_abs_diff(&cg, &ga) > 1e-3);
    }

    #[test]
    fn many_body_equivariance() {
        use crate::so3::linalg::matvec;
        use crate::so3::rotation::{wigner_d_real_block, Rot3};
        let mut rng = Rng::new(4);
        let l = 1usize;
        let rot = Rot3::random(&mut rng);
        let d = wigner_d_real_block(l, &rot);
        let d_out = wigner_d_real_block(2, &rot);
        let n = num_coeffs(l);
        let xs: Vec<Vec<f64>> = (0..3).map(|_| rng.normals(n)).collect();
        let rotated: Vec<Vec<f64>> =
            xs.iter().map(|x| matvec(&d, x, n, n)).collect();
        let a = many_body_gaunt(&rotated, l, 2, true);
        let b0 = many_body_gaunt(&xs, l, 2, true);
        let nn = num_coeffs(2);
        let b = matvec(&d_out, &b0, nn, nn);
        assert!(max_abs_diff(&a, &b) < 1e-8);
    }
}
