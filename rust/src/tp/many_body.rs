//! Equivariant Many-body Interactions: nu-fold tensor products
//! (paper Sec. 3.3 + Appendix C).
//!
//! Three evaluation strategies, matching the paper's comparison:
//!
//! * [`many_body_cg_fold`] — e3nn-style left fold of pairwise CG products
//!   with growing intermediate degree (the slow baseline),
//! * [`MaceStylePlan`] — MACE-style: precompute the *composed* coupling
//!   tensor C[k, i1..i_nu] once and contract (fast apply, memory grows as
//!   O(n^nu) — the "trades space for speed" row of Table 2),
//! * [`many_body_gaunt`] — the paper's method: convert once, chain 2D
//!   convolutions in the Fourier domain (sequential or divide-and-conquer
//!   order), project back once.
//! * [`ManyBodyPlan`] — the planned fast path: transform every operand
//!   once to real samples on the FINAL-size torus grid (pairwise
//!   two-for-one packed FFTs), collapse the whole chain to a pointwise
//!   real product, transform back once; the self-product variant does a
//!   single transform and a pointwise nu-th power.

use crate::fourier::complex::C64;
use crate::fourier::conv::conv2d_direct;
use crate::fourier::plan::{ConvPlan, ConvScratch};
use crate::so3::gaunt::gaunt_tensor_real;
use crate::tp::cg::CgPlan;
use crate::tp::gaunt::GauntPlan;
use crate::fourier::tables::{f2sh_contract, sh2f_panels, F2shPanelsT,
                             Sh2fPanels};
use crate::num_coeffs;

/// e3nn-style fold: ((x1 (x) x2) (x) x3) ... with CG couplings, keeping all
/// intermediate degrees up to `cap` (= min(sum of degrees, l_cap)).
pub fn many_body_cg_fold(xs: &[Vec<f64>], l: usize, l_out: usize,
                         l_cap: usize) -> Vec<f64> {
    assert!(!xs.is_empty());
    let mut acc = xs[0].clone();
    let mut l_acc = l;
    for x in &xs[1..] {
        let l_next = (l_acc + l).min(l_cap);
        let plan = CgPlan::new(l_acc, l, l_next);
        acc = plan.apply_sparse(&acc, x);
        l_acc = l_next;
    }
    acc.truncate(num_coeffs(l_out));
    acc
}

/// Gaunt-parameterized fold (same shape, Gaunt couplings) — the oracle for
/// the Fourier-domain strategies.
pub fn many_body_gaunt_fold(xs: &[Vec<f64>], l: usize, l_out: usize) -> Vec<f64> {
    assert!(!xs.is_empty());
    let mut acc = xs[0].clone();
    let mut l_acc = l;
    for x in &xs[1..] {
        let plan = GauntPlan::new(l_acc, l, l_acc + l,
                                  crate::tp::ConvMethod::Auto);
        acc = plan.apply(&acc, x);
        l_acc += l;
    }
    acc.truncate(num_coeffs(l_out));
    acc
}

/// The paper's many-body path: sh2f each operand once, convolve the grids
/// (sequential chain or divide-and-conquer tree), f2sh once at the end.
pub fn many_body_gaunt(xs: &[Vec<f64>], l: usize, l_out: usize,
                       divide_and_conquer: bool) -> Vec<f64> {
    assert!(!xs.is_empty());
    let nu = xs.len();
    let panels = sh2f_panels(l);
    let mut grids: Vec<(Vec<C64>, usize)> = xs
        .iter()
        .map(|x| (GauntPlan::sh2f(&panels, x), 2 * l + 1))
        .collect();
    let merged = if divide_and_conquer {
        // pairwise tree reduction
        while grids.len() > 1 {
            let mut next = Vec::with_capacity(grids.len().div_ceil(2));
            let mut it = grids.into_iter();
            while let Some((a, na)) = it.next() {
                match it.next() {
                    Some((b, nb)) => {
                        let out = conv2d_direct(&a, na, &b, nb);
                        next.push((out, na + nb - 1));
                    }
                    None => next.push((a, na)),
                }
            }
            grids = next;
        }
        grids.pop().unwrap()
    } else {
        let mut it = grids.into_iter();
        let (mut acc, mut n) = it.next().unwrap();
        for (b, nb) in it {
            acc = conv2d_direct(&acc, n, &b, nb);
            n = n + nb - 1;
        }
        (acc, n)
    };
    let (grid, n_side) = merged;
    let n_grid = (n_side - 1) / 2;
    debug_assert_eq!(n_grid, nu * l);
    let t3t = F2shPanelsT::build(l_out, n_grid);
    let mut x = vec![0.0; num_coeffs(l_out)];
    f2sh_contract(&t3t, &grid, &mut x);
    x
}

/// Planned many-body pipeline: every operand is transformed ONCE to real
/// samples on the final-size torus grid (power-of-two m >= 2 nu l + 1),
/// the nu-fold convolution collapses to a pointwise product of real
/// sample arrays, and one real-input forward FFT + f2sh projects back.
///
/// Versus the grid-domain chaining of [`many_body_gaunt`] (whose k-th
/// sequential convolution costs O((2kl+1)^2 (2l+1)^2)), this is
/// O(nu m^2 log m) total — and the operands' spectra are computed
/// pairwise two-for-one (grids from real SH coefficients are Hermitian,
/// so `INV2[G_a + i G_b]` transforms two at once).  For the MACE-style
/// self-product (all operands equal), [`ManyBodyPlan::apply_self`] does
/// ONE transform and a pointwise nu-th power.
pub struct ManyBodyPlan {
    pub nu: usize,
    pub l: usize,
    pub l_out: usize,
    panels: Sh2fPanels,
    t3t: F2shPanelsT,
    n_in: usize,   // 2l + 1
    n_side: usize, // 2 nu l + 1
    /// chain workspace: wrap maps for operand and final-product sizes,
    /// padded transform size, shared FFT tables (the same machinery the
    /// pairwise Hermitian path uses — one source of the wrap convention)
    chain: ConvPlan,
}

/// Caller-owned scratch for [`ManyBodyPlan`] applies: one per worker
/// thread; sized at plan build, never resized.
pub struct ManyBodyScratch {
    /// sh2f staging
    w: Vec<C64>,
    /// operand Fourier grids (pair packing)
    g1: Vec<C64>,
    g2: Vec<C64>,
    /// running real sample product (m x m)
    prod: Vec<f64>,
    /// final product grid (n_side x n_side)
    grid: Vec<C64>,
    /// planned-convolution workspace (packed transforms + projection)
    conv: ConvScratch,
}

impl ManyBodyPlan {
    pub fn new(nu: usize, l: usize, l_out: usize) -> Self {
        assert!(nu >= 1);
        assert!(l_out <= nu * l,
                "l_out={l_out} exceeds the nu*l={} product degree", nu * l);
        let n_in = 2 * l + 1;
        let n_side = 2 * nu * l + 1;
        ManyBodyPlan {
            nu,
            l,
            l_out,
            panels: sh2f_panels(l),
            t3t: F2shPanelsT::build(l_out, nu * l),
            n_in,
            n_side,
            chain: ConvPlan::for_chain(n_in, n_side),
        }
    }

    /// Fresh scratch sized for this plan (one per worker thread).
    pub fn scratch(&self) -> ManyBodyScratch {
        let nl = self.l + 1;
        let m = self.chain.m;
        ManyBodyScratch {
            w: vec![C64::default(); nl * nl],
            g1: vec![C64::default(); self.n_in * self.n_in],
            g2: vec![C64::default(); self.n_in * self.n_in],
            prod: vec![0.0; m * m],
            grid: vec![C64::default(); self.n_side * self.n_side],
            conv: self.chain.scratch(),
        }
    }

    /// Wrap-embed `grid` (n_in x n_in, centered) into `z` (m x m) via the
    /// chain plan's operand wrap map: `add_i` accumulates `i * grid` (the
    /// imaginary slot of the packed pair), plain assignment otherwise (z
    /// is pre-zeroed).
    fn wrap_grid(&self, grid: &[C64], z: &mut [C64], add_i: bool) {
        let (n, m) = (self.n_in, self.chain.m);
        let wrap = &self.chain.wrap1;
        for i in 0..n {
            let r = wrap[i] * m;
            for j in 0..n {
                let g = grid[i * n + j];
                let cell = &mut z[r + wrap[j]];
                if add_i {
                    cell.re -= g.im;
                    cell.im += g.re;
                } else {
                    *cell = g;
                }
            }
        }
    }

    /// Back half shared by apply / apply_self: product samples ->
    /// centered grid (via the chain plan) -> SH.
    fn project_into(&self, scratch: &mut ManyBodyScratch, out: &mut [f64]) {
        self.chain
            .grid_from_samples_into(&scratch.prod, &mut scratch.grid,
                                    &mut scratch.conv);
        f2sh_contract(&self.t3t, &scratch.grid, out);
    }

    /// nu-fold Gaunt product of `xs` (each `num_coeffs(l)` long),
    /// truncated to degree `l_out`.  Matches [`many_body_gaunt_fold`].
    pub fn apply(&self, xs: &[Vec<f64>]) -> Vec<f64> {
        let mut out = vec![0.0; num_coeffs(self.l_out)];
        let mut scratch = self.scratch();
        self.apply_into(xs, &mut out, &mut scratch);
        out
    }

    /// [`ManyBodyPlan::apply`] over caller scratch: allocation-free.
    pub fn apply_into(
        &self, xs: &[Vec<f64>], out: &mut [f64],
        scratch: &mut ManyBodyScratch,
    ) {
        assert_eq!(xs.len(), self.nu);
        scratch.prod.fill(1.0);
        for pair in xs.chunks(2) {
            let z = &mut scratch.conv.z;
            z.fill(C64::default());
            GauntPlan::sh2f_into(&self.panels, &pair[0], &mut scratch.g1,
                                 &mut scratch.w);
            self.wrap_grid(&scratch.g1, z, false);
            if pair.len() == 2 {
                GauntPlan::sh2f_into(&self.panels, &pair[1], &mut scratch.g2,
                                     &mut scratch.w);
                self.wrap_grid(&scratch.g2, z, true);
            }
            self.chain.fft.fft2_inplace(z, true, &mut scratch.conv.col);
            if pair.len() == 2 {
                for (p, zv) in scratch.prod.iter_mut().zip(z.iter()) {
                    *p *= zv.re * zv.im;
                }
            } else {
                for (p, zv) in scratch.prod.iter_mut().zip(z.iter()) {
                    *p *= zv.re;
                }
            }
        }
        self.project_into(scratch, out);
    }

    /// MACE-style self-product `x (x) x (x) ... (x) x` (nu factors): ONE
    /// transform, a pointwise nu-th power, one transform back.
    pub fn apply_self(&self, x: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; num_coeffs(self.l_out)];
        let mut scratch = self.scratch();
        self.apply_self_into(x, &mut out, &mut scratch);
        out
    }

    /// [`ManyBodyPlan::apply_self`] over caller scratch: allocation-free.
    pub fn apply_self_into(
        &self, x: &[f64], out: &mut [f64], scratch: &mut ManyBodyScratch,
    ) {
        GauntPlan::sh2f_into(&self.panels, x, &mut scratch.g1, &mut scratch.w);
        let z = &mut scratch.conv.z;
        z.fill(C64::default());
        self.wrap_grid(&scratch.g1, z, false);
        self.chain.fft.fft2_inplace(z, true, &mut scratch.conv.col);
        for (p, zv) in scratch.prod.iter_mut().zip(z.iter()) {
            *p = zv.re.powi(self.nu as i32);
        }
        self.project_into(scratch, out);
    }
}

/// MACE-style precomputed composite coupling: C[k, i1, ..., i_nu] built by
/// composing pairwise Gaunt tensors once; apply is a dense contraction.
/// Memory O(n_out * n^nu) — the space-for-speed trade of Table 2.
pub struct MaceStylePlan {
    pub nu: usize,
    pub l: usize,
    pub l_out: usize,
    n_in: usize,
    n_out: usize,
    /// tensor[k * n^nu + multi-index(i1..i_nu)]
    tensor: Vec<f64>,
}

impl MaceStylePlan {
    pub fn new(nu: usize, l: usize, l_out: usize) -> Self {
        assert!(nu >= 2);
        let n_in = num_coeffs(l);
        // start with pairwise tensor to degree 2l, then absorb one operand
        // at a time (intermediate degree grows exactly, no truncation until
        // the last step).
        let mut l_acc = 2 * l;
        let mut t = gaunt_tensor_real(l, l, l_acc); // [k, i, j]
        let mut rank = 2usize;
        while rank < nu {
            let l_next = if rank + 1 == nu { l_out } else { l_acc + l };
            let g = gaunt_tensor_real(l_acc, l, l_next); // [k2, p, i_new]
            let n_acc = num_coeffs(l_acc);
            let n_next = num_coeffs(l_next);
            let width = n_in.pow(rank as u32);
            let mut t2 = vec![0.0; n_next * width * n_in];
            for k2 in 0..n_next {
                for p in 0..n_acc {
                    for inew in 0..n_in {
                        let gv = g[(k2 * n_acc + p) * n_in + inew];
                        if gv == 0.0 {
                            continue;
                        }
                        let src = &t[p * width..(p + 1) * width];
                        let dst = &mut t2
                            [(k2 * width * n_in)..((k2 + 1) * width * n_in)];
                        for (w, sv) in src.iter().enumerate() {
                            if *sv != 0.0 {
                                dst[w * n_in + inew] += gv * sv;
                            }
                        }
                    }
                }
            }
            t = t2;
            l_acc = l_next;
            rank += 1;
        }
        // if nu == 2, truncate the pairwise tensor to l_out
        let (tensor, l_final) = if nu == 2 {
            let n_out = num_coeffs(l_out);
            (t[..n_out * n_in * n_in].to_vec(), l_out)
        } else {
            (t, l_acc)
        };
        debug_assert_eq!(l_final, l_out);
        MaceStylePlan {
            nu,
            l,
            l_out,
            n_in,
            n_out: num_coeffs(l_out),
            tensor,
        }
    }

    pub fn memory_bytes(&self) -> usize {
        self.tensor.len() * std::mem::size_of::<f64>()
    }

    /// Contract against nu copies (here: the same feature, as in MACE's
    /// B-features) — specialized for nu in 2..=4.
    pub fn apply_self(&self, x: &[f64]) -> Vec<f64> {
        let n = self.n_in;
        let mut out = vec![0.0; self.n_out];
        match self.nu {
            2 => {
                for k in 0..self.n_out {
                    let blk = &self.tensor[k * n * n..(k + 1) * n * n];
                    let mut acc = 0.0;
                    for i in 0..n {
                        if x[i] == 0.0 {
                            continue;
                        }
                        let row = &blk[i * n..(i + 1) * n];
                        let mut s = 0.0;
                        for j in 0..n {
                            s += row[j] * x[j];
                        }
                        acc += x[i] * s;
                    }
                    out[k] = acc;
                }
            }
            3 => {
                let w = n * n * n;
                for k in 0..self.n_out {
                    let blk = &self.tensor[k * w..(k + 1) * w];
                    let mut acc = 0.0;
                    for i in 0..n {
                        let xi = x[i];
                        if xi == 0.0 {
                            continue;
                        }
                        for j in 0..n {
                            let xij = xi * x[j];
                            if xij == 0.0 {
                                continue;
                            }
                            let row = &blk[(i * n + j) * n..(i * n + j + 1) * n];
                            let mut s = 0.0;
                            for p in 0..n {
                                s += row[p] * x[p];
                            }
                            acc += xij * s;
                        }
                    }
                    out[k] = acc;
                }
            }
            4 => {
                let w = n * n * n * n;
                for k in 0..self.n_out {
                    let blk = &self.tensor[k * w..(k + 1) * w];
                    let mut acc = 0.0;
                    for i in 0..n {
                        let xi = x[i];
                        if xi == 0.0 {
                            continue;
                        }
                        for j in 0..n {
                            let xij = xi * x[j];
                            for p in 0..n {
                                let xijp = xij * x[p];
                                if xijp == 0.0 {
                                    continue;
                                }
                                let row = &blk[((i * n + j) * n + p) * n..];
                                let mut s = 0.0;
                                for q in 0..n {
                                    s += row[q] * x[q];
                                }
                                acc += xijp * s;
                            }
                        }
                    }
                    out[k] = acc;
                }
            }
            _ => panic!("MaceStylePlan supports nu in 2..=4"),
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::max_abs_diff;
    use crate::util::rng::Rng;

    #[test]
    fn gaunt_grid_chain_matches_fold() {
        let mut rng = Rng::new(0);
        let l = 1usize;
        for nu in 2..=4usize {
            let xs: Vec<Vec<f64>> =
                (0..nu).map(|_| rng.normals(num_coeffs(l))).collect();
            let want = many_body_gaunt_fold(&xs, l, 2);
            let seq = many_body_gaunt(&xs, l, 2, false);
            let dc = many_body_gaunt(&xs, l, 2, true);
            assert!(max_abs_diff(&want, &seq) < 1e-9, "seq nu={nu}");
            assert!(max_abs_diff(&want, &dc) < 1e-9, "dc nu={nu}");
        }
    }

    #[test]
    fn dc_equals_sequential_l2() {
        let mut rng = Rng::new(1);
        let l = 2usize;
        let xs: Vec<Vec<f64>> =
            (0..3).map(|_| rng.normals(num_coeffs(l))).collect();
        let seq = many_body_gaunt(&xs, l, 2, false);
        let dc = many_body_gaunt(&xs, l, 2, true);
        assert!(max_abs_diff(&seq, &dc) < 1e-9);
    }

    #[test]
    fn mace_style_matches_gaunt_fold() {
        let mut rng = Rng::new(2);
        for (nu, l) in [(2usize, 2usize), (3, 1), (3, 2), (4, 1)] {
            let x = rng.normals(num_coeffs(l));
            let xs: Vec<Vec<f64>> = (0..nu).map(|_| x.clone()).collect();
            let want = many_body_gaunt_fold(&xs, l, l);
            let plan = MaceStylePlan::new(nu, l, l);
            let got = plan.apply_self(&x);
            assert!(max_abs_diff(&got, &want) < 1e-8,
                    "nu={nu} l={l}: {}", max_abs_diff(&got, &want));
        }
    }

    #[test]
    fn planned_pipeline_matches_fold() {
        let mut rng = Rng::new(5);
        for (nu, l, l_out) in [(1usize, 2usize, 2usize), (2, 1, 2), (2, 2, 3),
                               (3, 1, 2), (3, 2, 4), (4, 1, 3)] {
            let xs: Vec<Vec<f64>> =
                (0..nu).map(|_| rng.normals(num_coeffs(l))).collect();
            let want = if nu == 1 {
                let mut t = xs[0].clone();
                t.truncate(num_coeffs(l_out.min(l)));
                t.resize(num_coeffs(l_out), 0.0);
                t
            } else {
                many_body_gaunt_fold(&xs, l, l_out)
            };
            let plan = ManyBodyPlan::new(nu, l, l_out);
            let got = plan.apply(&xs);
            assert!(max_abs_diff(&got, &want) < 1e-8,
                    "nu={nu} l={l} l_out={l_out}: {}",
                    max_abs_diff(&got, &want));
        }
    }

    #[test]
    fn planned_self_product_matches_apply() {
        let mut rng = Rng::new(6);
        for (nu, l) in [(2usize, 2usize), (3, 1), (3, 2), (4, 1)] {
            let x = rng.normals(num_coeffs(l));
            let xs: Vec<Vec<f64>> = (0..nu).map(|_| x.clone()).collect();
            let plan = ManyBodyPlan::new(nu, l, l);
            let a = plan.apply(&xs);
            let b = plan.apply_self(&x);
            assert!(max_abs_diff(&a, &b) < 1e-9, "nu={nu} l={l}");
            let want = many_body_gaunt_fold(&xs, l, l);
            assert!(max_abs_diff(&b, &want) < 1e-8,
                    "nu={nu} l={l}: {}", max_abs_diff(&b, &want));
        }
    }

    #[test]
    fn mace_style_memory_grows() {
        let m2 = MaceStylePlan::new(2, 1, 2).memory_bytes();
        let m3 = MaceStylePlan::new(3, 1, 2).memory_bytes();
        assert!(m3 > 2 * m2);
    }

    #[test]
    fn cg_fold_differs_from_gaunt_fold() {
        // CG keeps odd-parity paths; the two many-body features disagree
        let mut rng = Rng::new(3);
        let l = 1usize;
        let xs: Vec<Vec<f64>> =
            (0..3).map(|_| rng.normals(num_coeffs(l))).collect();
        let cg = many_body_cg_fold(&xs, l, 2, 3);
        let ga = many_body_gaunt_fold(&xs, l, 2);
        assert!(max_abs_diff(&cg, &ga) > 1e-3);
    }

    #[test]
    fn many_body_equivariance() {
        use crate::so3::linalg::matvec;
        use crate::so3::rotation::{wigner_d_real_block, Rot3};
        let mut rng = Rng::new(4);
        let l = 1usize;
        let rot = Rot3::random(&mut rng);
        let d = wigner_d_real_block(l, &rot);
        let d_out = wigner_d_real_block(2, &rot);
        let n = num_coeffs(l);
        let xs: Vec<Vec<f64>> = (0..3).map(|_| rng.normals(n)).collect();
        let rotated: Vec<Vec<f64>> =
            xs.iter().map(|x| matvec(&d, x, n, n)).collect();
        let a = many_body_gaunt(&rotated, l, 2, true);
        let b0 = many_body_gaunt(&xs, l, 2, true);
        let nn = num_coeffs(2);
        let b = matvec(&d_out, &b0, nn, nn);
        assert!(max_abs_diff(&a, &b) < 1e-8);
    }
}
