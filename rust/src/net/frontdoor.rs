//! The front door: one listening endpoint fronting N replicas.
//!
//! Routing (DESIGN.md §14): a submission goes to the live,
//! non-draining replica whose largest shape bucket fits the task most
//! tightly; ties break to the fewest outstanding submissions, then
//! round-robin.  A prober thread pings every replica on a short
//! interval — a failed probe marks the replica down (routes move away
//! instantly) and keeps trying to reconnect, so a restarted replica
//! rejoins without operator action.
//!
//! Failure semantics:
//!
//! * a replica dying mid-task surfaces upstream as `Dropped`; if the
//!   task is idempotent (not `MdRollout`) and no frames were forwarded
//!   yet, the front door retries it on another replica within the
//!   deadline budget — otherwise the typed error forwards downstream;
//! * admission backpressure (`Overloaded { retry_after }`) forwards
//!   verbatim: wire-visible backpressure instead of silent queueing;
//! * downstream `cancel` (or the downstream connection dying)
//!   propagates upstream even across a failover, so replicas never run
//!   work nobody is waiting for;
//! * `drain` stops admission at the front door (typed `Rejected`),
//!   while in-flight work finishes.
//!
//! **Supervision.** A front door that spawned its own replica processes
//! (`frontdoor --spawn-replicas N`) can [`FrontDoor::supervise`] them:
//! when the prober finds a supervised replica unreachable AND its child
//! process has exited, it respawns the child from the recorded argv
//! with bounded exponential backoff, up to [`RespawnPolicy::max_restarts`]
//! total restarts — the restarted replica then rejoins routing through
//! the normal probe/reconnect path, without operator action.  Replicas
//! it did not spawn are never touched (their lifecycle belongs to
//! whoever started them).

use std::collections::HashMap;
use std::io;
use std::process::Child;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::ReplyMsg;
use crate::coordinator::{HealthState, MetricsSnapshot, ServiceError, Task};

use super::client::NetClient;
use super::frame::{read_frame, write_frame, WireError, VERSION};
use super::proto::{decode_client, encode_server, ClientMsg, ServerMsg};
use super::{poke, spawn_acceptor, Addr, Conn, ConnRegistry, Listener};

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(5);

/// Front-door tuning.
#[derive(Clone, Copy, Debug)]
pub struct FrontDoorConfig {
    /// how often the prober pings each replica (and retries dead ones)
    pub probe_interval: Duration,
    /// ping budget before a replica is declared down
    pub probe_timeout: Duration,
    /// `retry_after` hint when no replica can take a submission
    pub retry_after: Duration,
}

impl Default for FrontDoorConfig {
    fn default() -> Self {
        FrontDoorConfig {
            probe_interval: Duration::from_millis(100),
            probe_timeout: Duration::from_secs(2),
            retry_after: Duration::from_millis(50),
        }
    }
}

/// How the front door restarts replicas it spawned itself.
#[derive(Clone, Copy, Debug)]
pub struct RespawnPolicy {
    /// total respawns allowed per replica before the supervisor gives
    /// up (the replica then stays down like an unsupervised one)
    pub max_restarts: usize,
    /// delay before the second respawn attempt (the first is immediate)
    pub backoff_initial: Duration,
    /// backoff cap; the delay doubles per attempt up to this
    pub backoff_max: Duration,
}

impl Default for RespawnPolicy {
    fn default() -> Self {
        RespawnPolicy {
            max_restarts: 5,
            backoff_initial: Duration::from_millis(200),
            backoff_max: Duration::from_secs(5),
        }
    }
}

/// Supervision state for one spawned replica child process.
struct Supervisor {
    /// respawn argv (`argv[0]` = executable path)
    cmd: Vec<String>,
    policy: RespawnPolicy,
    /// the current child; `None` between a reaped exit and the respawn
    child: Option<Child>,
    restarts: usize,
    backoff: Duration,
    /// earliest time of the next respawn attempt
    next_attempt: Instant,
}

/// One routed-to replica: its address plus live connection state.
struct ReplicaHandle {
    addr: Addr,
    /// `Some` while the replica answers probes; `None` while down
    client: Mutex<Option<Arc<NetClient>>>,
    /// submissions currently routed here (the load-balance signal)
    outstanding: AtomicUsize,
    /// the replica reported `Draining` on its last pong
    draining: AtomicBool,
    /// largest admissible structure (from its handshake)
    max_atoms: AtomicUsize,
    /// `Some` when the front door owns this replica's process
    supervisor: Mutex<Option<Supervisor>>,
}

impl ReplicaHandle {
    fn live(&self) -> Option<Arc<NetClient>> {
        lock(&self.client).as_ref().filter(|c| !c.is_dead()).cloned()
    }

    /// Remove from routing; in-flight pumps keep their own `Arc` and
    /// resolve through the dead connection's typed teardown.
    fn mark_down(&self) {
        lock(&self.client).take();
    }

    fn try_connect(&self) {
        let mut slot = lock(&self.client);
        if slot.as_ref().map_or(false, |c| !c.is_dead()) {
            return;
        }
        *slot = match NetClient::connect_named(&self.addr, "frontdoor") {
            Ok(c) => {
                self.max_atoms.store(c.max_atoms(), Ordering::Relaxed);
                self.draining.store(false, Ordering::Relaxed);
                Some(Arc::new(c))
            }
            Err(_) => None,
        };
    }

    /// A healthy reconnect ends the current backoff episode: the next
    /// death starts the exponential schedule from the beginning.
    fn note_healthy(&self) {
        if let Some(sup) = lock(&self.supervisor).as_mut() {
            sup.backoff = sup.policy.backoff_initial;
        }
    }

    /// Respawn a supervised child that has actually exited.  Called by
    /// the prober while the replica is unreachable; a child that is
    /// still running (booting, or slow) is left alone — the probe will
    /// reach it or its exit will land here on a later tick.
    fn supervise_tick(&self) {
        let mut slot = lock(&self.supervisor);
        let Some(sup) = slot.as_mut() else { return };
        if let Some(child) = sup.child.as_mut() {
            match child.try_wait() {
                Ok(None) => return, // alive; give it time to bind
                Ok(Some(_)) | Err(_) => sup.child = None, // exited, reaped
            }
        }
        let now = Instant::now();
        if now < sup.next_attempt || sup.restarts >= sup.policy.max_restarts
        {
            return;
        }
        sup.restarts += 1;
        sup.next_attempt = now + sup.backoff;
        sup.backoff = (sup.backoff * 2).min(sup.policy.backoff_max);
        if let Ok(child) = std::process::Command::new(&sup.cmd[0])
            .args(&sup.cmd[1..])
            .spawn()
        {
            sup.child = Some(child);
        }
    }

    /// Kill and reap the supervised child, if any (shutdown path).
    fn kill_supervised(&self) {
        if let Some(sup) = lock(&self.supervisor).as_mut() {
            if let Some(mut child) = sup.child.take() {
                let _ = child.kill();
                let _ = child.wait();
            }
        }
    }
}

struct FdShared {
    replicas: Vec<Arc<ReplicaHandle>>,
    cfg: FrontDoorConfig,
    stop: Arc<AtomicBool>,
    draining: AtomicBool,
    /// the front door's own request ledger (reconciles like a
    /// service's: every admitted submission ends in exactly one bucket)
    metrics: Metrics,
    rr: AtomicUsize,
    conns: ConnRegistry,
}

impl FdShared {
    /// All replicas currently usable for new work.
    fn candidates(&self, n_atoms: usize) -> Vec<(usize, Arc<NetClient>)> {
        self.replicas
            .iter()
            .enumerate()
            .filter(|(_, r)| !r.draining.load(Ordering::Relaxed))
            .filter(|(_, r)| r.max_atoms.load(Ordering::Relaxed) >= n_atoms)
            .filter_map(|(i, r)| r.live().map(|c| (i, c)))
            .collect()
    }

    /// Pick the tightest-bucket, least-loaded candidate.
    fn route(&self, n_atoms: usize) -> Option<(usize, Arc<NetClient>)> {
        // snapshot each candidate's (bucket, outstanding) key once:
        // the atomics move under concurrent routing, and a key re-read
        // between the sort and the tie filter could match nothing
        let mut keyed: Vec<((usize, usize), (usize, Arc<NetClient>))> = self
            .candidates(n_atoms)
            .into_iter()
            .map(|(i, c)| {
                let r = &self.replicas[i];
                (
                    (
                        r.max_atoms.load(Ordering::Relaxed),
                        r.outstanding.load(Ordering::Relaxed),
                    ),
                    (i, c),
                )
            })
            .collect();
        if keyed.is_empty() {
            return None;
        }
        keyed.sort_by_key(|(k, _)| *k);
        let best = keyed[0].0;
        let tied: Vec<_> = keyed
            .into_iter()
            .filter(|(k, _)| *k == best)
            .map(|(_, rc)| rc)
            .collect();
        let pick = self.rr.fetch_add(1, Ordering::Relaxed) % tied.len();
        tied.into_iter().nth(pick)
    }

    fn aggregate_health(&self) -> HealthState {
        if self.draining.load(Ordering::Relaxed) {
            return HealthState::Draining;
        }
        let mut any_live = false;
        for r in &self.replicas {
            if r.live().is_some() && !r.draining.load(Ordering::Relaxed) {
                any_live = true;
            }
        }
        if any_live {
            HealthState::Healthy
        } else {
            HealthState::Shedding
        }
    }

    /// Own ledger merged with every live replica's.
    fn aggregate_stats(&self) -> MetricsSnapshot {
        let mut snap = self.metrics.snapshot();
        for r in &self.replicas {
            if let Some(c) = r.live() {
                if let Ok(s) = c.stats(self.cfg.probe_timeout) {
                    snap.merge(&s);
                }
            }
        }
        snap
    }

    fn hello_shape(&self) -> (usize, Vec<usize>) {
        let mut max_atoms = 0usize;
        let mut buckets: Vec<usize> = Vec::new();
        for r in &self.replicas {
            if let Some(c) = r.live() {
                max_atoms = max_atoms.max(c.max_atoms());
                for &b in c.buckets() {
                    if !buckets.contains(&b) {
                        buckets.push(b);
                    }
                }
            }
        }
        if max_atoms == 0 {
            // no replica is up yet; don't reject everything at
            // handshake time — admission is rechecked per submission
            max_atoms = 1 << 20;
        }
        buckets.sort_unstable();
        (max_atoms, buckets)
    }
}

/// A running front door.
pub struct FrontDoor {
    shared: Arc<FdShared>,
    bound: Vec<Addr>,
    acceptors: Vec<std::thread::JoinHandle<()>>,
    prober: Option<std::thread::JoinHandle<()>>,
}

impl FrontDoor {
    /// Bind `listen` and start routing to `replica_addrs`.  Replicas
    /// need not be up yet — the prober connects as they appear.
    pub fn serve(
        replica_addrs: &[Addr], listen: &[Addr], cfg: FrontDoorConfig,
    ) -> io::Result<FrontDoor> {
        let replicas: Vec<Arc<ReplicaHandle>> = replica_addrs
            .iter()
            .map(|addr| {
                Arc::new(ReplicaHandle {
                    addr: addr.clone(),
                    client: Mutex::new(None),
                    outstanding: AtomicUsize::new(0),
                    draining: AtomicBool::new(false),
                    max_atoms: AtomicUsize::new(0),
                    supervisor: Mutex::new(None),
                })
            })
            .collect();
        let shared = Arc::new(FdShared {
            replicas,
            cfg,
            stop: Arc::new(AtomicBool::new(false)),
            draining: AtomicBool::new(false),
            metrics: Metrics::new(),
            rr: AtomicUsize::new(0),
            conns: ConnRegistry::new(),
        });
        // eager first connect so the first submission doesn't wait a
        // probe interval
        for r in &shared.replicas {
            r.try_connect();
        }
        let prober = {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("frontdoor-prober".to_string())
                .spawn(move || prober_loop(shared))
                .expect("spawn prober")
        };
        let mut bound = Vec::new();
        let mut acceptors = Vec::new();
        for addr in listen {
            let (listener, actual) = Listener::bind(addr)?;
            let handler: Arc<dyn Fn(Conn) + Send + Sync> = {
                let shared = shared.clone();
                Arc::new(move |conn: Conn| handle_conn(conn, shared.clone()))
            };
            acceptors.push(spawn_acceptor(
                listener,
                shared.stop.clone(),
                "frontdoor".to_string(),
                handler,
            ));
            bound.push(actual);
        }
        Ok(FrontDoor { shared, bound, acceptors, prober: Some(prober) })
    }

    pub fn bound(&self) -> &[Addr] {
        &self.bound
    }

    /// Stop admitting new submissions (typed `Rejected`); in-flight
    /// work keeps running.
    pub fn drain(&self) {
        self.shared.draining.store(true, Ordering::Relaxed);
    }

    /// The front door's own (unmerged) ledger.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.metrics.snapshot()
    }

    /// Adopt a replica child process this front door spawned: when the
    /// prober finds replica `replica` unreachable and the child has
    /// exited, it is respawned from `cmd` (`argv[0]` = executable) under
    /// `policy`'s bounded backoff.  `replica` indexes the
    /// `replica_addrs` given to [`FrontDoor::serve`].
    pub fn supervise(
        &self, replica: usize, child: Child, cmd: Vec<String>,
        policy: RespawnPolicy,
    ) {
        assert!(!cmd.is_empty(), "respawn argv needs the executable");
        *lock(&self.shared.replicas[replica].supervisor) = Some(Supervisor {
            cmd,
            policy,
            child: Some(child),
            restarts: 0,
            backoff: policy.backoff_initial,
            next_attempt: Instant::now(),
        });
    }

    /// Per-replica respawn counts (0 for unsupervised replicas).
    pub fn respawn_counts(&self) -> Vec<usize> {
        self.shared
            .replicas
            .iter()
            .map(|r| lock(&r.supervisor).as_ref().map_or(0, |s| s.restarts))
            .collect()
    }

    /// Replica indices currently live (for tests/CLI status).
    pub fn live_replicas(&self) -> Vec<usize> {
        self.shared
            .replicas
            .iter()
            .enumerate()
            .filter(|(_, r)| r.live().is_some())
            .map(|(i, _)| i)
            .collect()
    }

    pub fn shutdown(mut self) {
        self.shared.stop.store(true, Ordering::Relaxed);
        for addr in &self.bound {
            poke(addr);
        }
        for h in self.acceptors.drain(..) {
            let _ = h.join();
        }
        if let Some(p) = self.prober.take() {
            let _ = p.join();
        }
        self.shared.conns.sever_all();
        for r in &self.shared.replicas {
            r.mark_down();
            r.kill_supervised();
        }
        for addr in &self.bound {
            if let Addr::Unix(p) = addr {
                let _ = std::fs::remove_file(p);
            }
        }
    }
}

fn prober_loop(shared: Arc<FdShared>) {
    while !shared.stop.load(Ordering::Relaxed) {
        for r in &shared.replicas {
            if shared.stop.load(Ordering::Relaxed) {
                return;
            }
            let live = r.live();
            match live {
                None => {
                    r.try_connect();
                    if r.live().is_some() {
                        r.note_healthy();
                    } else {
                        r.supervise_tick();
                    }
                }
                Some(c) => match c.ping(shared.cfg.probe_timeout) {
                    Ok((health, _depth)) => {
                        r.draining.store(
                            health == HealthState::Draining,
                            Ordering::Relaxed,
                        );
                    }
                    Err(_) => r.mark_down(),
                },
            }
        }
        std::thread::sleep(shared.cfg.probe_interval);
    }
}

// ---------------------------------------------------------------------
// downstream connections
// ---------------------------------------------------------------------

/// Cancel state for one downstream submission, shared between the
/// reader (which sees `cancel` messages / teardown) and the routing
/// thread (which knows where the task currently lives).
struct CancelCell {
    canceled: AtomicBool,
    upstream: Mutex<Option<(Arc<NetClient>, u64)>>,
}

impl CancelCell {
    /// Flag + forward to wherever the task is right now.
    fn cancel(&self) {
        self.canceled.store(true, Ordering::Relaxed);
        if let Some((client, seq)) = lock(&self.upstream).clone() {
            client.send_wire_cancel(seq);
        }
    }
}

type Inflight = Arc<Mutex<HashMap<u64, Arc<CancelCell>>>>;

fn handle_conn(conn: Conn, shared: Arc<FdShared>) {
    // registered for FrontDoor::shutdown to sever; deregistered below
    // so a long-lived front door doesn't leak one fd per connection
    let reg = shared.conns.register(&conn);
    let teardown_conn = match conn.try_clone() {
        Ok(c) => c,
        Err(_) => {
            shared.conns.deregister(reg);
            conn.shutdown_both();
            return;
        }
    };
    let inflight: Inflight = Arc::new(Mutex::new(HashMap::new()));
    conn_loop(conn, &shared, &inflight);
    // downstream gone: propagate cancellation upstream for everything
    // still in flight so no replica runs abandoned work
    for (_, cell) in lock(&inflight).drain() {
        cell.cancel();
    }
    teardown_conn.shutdown_both();
    shared.conns.deregister(reg);
}

fn conn_loop(mut conn: Conn, shared: &Arc<FdShared>, inflight: &Inflight) {
    let _ = conn.set_read_timeout(Some(HANDSHAKE_TIMEOUT));
    match read_frame(&mut conn).and_then(|p| decode_client(&p)) {
        Ok(ClientMsg::Hello { version, .. }) if version == VERSION as u64 => {}
        _ => return,
    }
    let writer = match conn.try_clone() {
        Ok(c) => Arc::new(Mutex::new(c)),
        Err(_) => return,
    };
    let (max_atoms, buckets) = shared.hello_shape();
    if send(&writer, &ServerMsg::HelloAck {
        version: VERSION as u64,
        max_atoms,
        buckets,
    })
    .is_err()
    {
        return;
    }
    let _ = conn.set_read_timeout(None);

    loop {
        let msg = match read_frame(&mut conn) {
            Ok(p) => match decode_client(&p) {
                Ok(m) => m,
                Err(_) => return,
            },
            Err(WireError::Closed) => return,
            Err(_) => return,
        };
        match msg {
            ClientMsg::Submit { seq, deadline_ms, model, task } => {
                let cell = Arc::new(CancelCell {
                    canceled: AtomicBool::new(false),
                    upstream: Mutex::new(None),
                });
                lock(inflight).insert(seq, cell.clone());
                let shared = shared.clone();
                let writer = writer.clone();
                let inflight = inflight.clone();
                let _ = std::thread::Builder::new()
                    .name(format!("route-{seq}"))
                    .spawn(move || {
                        serve_submit(
                            &shared, &writer, seq, deadline_ms, model, task,
                            &cell,
                        );
                        lock(&inflight).remove(&seq);
                    });
            }
            ClientMsg::Cancel { seq } => {
                if let Some(cell) = lock(inflight).get(&seq).cloned() {
                    cell.cancel();
                }
            }
            ClientMsg::Ping => {
                let depth: usize = shared
                    .replicas
                    .iter()
                    .map(|r| r.outstanding.load(Ordering::Relaxed))
                    .sum();
                if send(&writer, &ServerMsg::Pong {
                    health: shared.aggregate_health(),
                    queue_depth: depth,
                })
                .is_err()
                {
                    return;
                }
            }
            ClientMsg::Stats => {
                if send(&writer, &ServerMsg::StatsAck {
                    metrics: shared.aggregate_stats(),
                })
                .is_err()
                {
                    return;
                }
            }
            ClientMsg::Drain => {
                shared.draining.store(true, Ordering::Relaxed);
            }
            ClientMsg::Bye => return,
            ClientMsg::Hello { .. } => {}
        }
    }
}

fn send(writer: &Arc<Mutex<Conn>>, msg: &ServerMsg) -> Result<(), WireError> {
    let mut w = lock(writer);
    write_frame(&mut *w, &encode_server(msg))
}

/// Route one submission, with failover, and write exactly one `Done`
/// downstream.  The front door's ledger is classified here — a single
/// point, so `requests = responses + failed + canceled + expired`
/// reconciles by construction.
fn serve_submit(
    shared: &Arc<FdShared>, writer: &Arc<Mutex<Conn>>, seq: u64,
    deadline_ms: Option<u64>, model: Option<String>, task: Task,
    cell: &Arc<CancelCell>,
) {
    let start = Instant::now();
    let result = route_with_failover(
        shared, writer, seq, deadline_ms, model, task, cell, start,
    );
    // ---- classify into the ledger, mirroring service semantics:
    // rejections/sheds are NOT counted as admitted requests ----
    let m = &shared.metrics;
    match &result {
        Ok(()) => {
            m.requests.fetch_add(1, Ordering::Relaxed);
            m.responses.fetch_add(1, Ordering::Relaxed);
            m.latency.record_ns(start.elapsed().as_nanos() as u64);
        }
        Err(ServiceError::Canceled) => {
            m.requests.fetch_add(1, Ordering::Relaxed);
            m.canceled.fetch_add(1, Ordering::Relaxed);
        }
        Err(ServiceError::DeadlineExceeded) => {
            m.requests.fetch_add(1, Ordering::Relaxed);
            m.expired.fetch_add(1, Ordering::Relaxed);
        }
        Err(ServiceError::Rejected(_)) => {
            m.rejected.fetch_add(1, Ordering::Relaxed);
        }
        Err(ServiceError::Overloaded { .. }) => {
            m.rejected.fetch_add(1, Ordering::Relaxed);
            m.shed.fetch_add(1, Ordering::Relaxed);
        }
        Err(_) => {
            m.requests.fetch_add(1, Ordering::Relaxed);
            m.failed.fetch_add(1, Ordering::Relaxed);
        }
    }
    let final_result = match result {
        Ok(()) => return, // Done(Ok) was already streamed downstream
        Err(e) => Err(e),
    };
    let _ = send(writer, &ServerMsg::Done { seq, result: final_result });
}

/// The failover loop.  `Ok(())` means a successful `Done(Ok(..))` was
/// already forwarded downstream (replies stream through as they
/// arrive); `Err` is the typed failure for `serve_submit` to send.
#[allow(clippy::too_many_arguments)]
fn route_with_failover(
    shared: &Arc<FdShared>, writer: &Arc<Mutex<Conn>>, seq: u64,
    deadline_ms: Option<u64>, model: Option<String>, task: Task,
    cell: &Arc<CancelCell>, start: Instant,
) -> Result<(), ServiceError> {
    // a retry may not duplicate observable effects: streaming tasks
    // re-run frames the client may already hold
    let idempotent = !matches!(task, Task::MdRollout { .. });
    // at most one attempt per configured replica, plus one grace try
    let max_attempts = shared.replicas.len().max(1) + 1;
    for _attempt in 0..max_attempts {
        if cell.canceled.load(Ordering::Relaxed) {
            return Err(ServiceError::Canceled);
        }
        if shared.draining.load(Ordering::Relaxed) {
            return Err(ServiceError::Rejected(
                "front door is draining; no new work is admitted".to_string(),
            ));
        }
        // remaining deadline budget, decremented across failovers
        let remaining_ms = match deadline_ms {
            None => None,
            Some(total) => {
                let elapsed = start.elapsed().as_millis() as u64;
                if elapsed >= total {
                    return Err(ServiceError::DeadlineExceeded);
                }
                Some(total - elapsed)
            }
        };
        let (idx, client) = match shared.route(task.n_atoms_max()) {
            Some(rc) => rc,
            None => {
                return Err(ServiceError::Overloaded {
                    retry_after: shared.cfg.retry_after,
                })
            }
        };
        let handle = &shared.replicas[idx];
        let raw = match client.submit_task(
            task.clone(),
            remaining_ms,
            model.clone(),
        ) {
            Ok(raw) => raw,
            Err(ServiceError::Dropped(_)) => {
                // connection died under us: mark down and fail over
                handle.mark_down();
                continue;
            }
            // any other verdict (Rejected, Overloaded, ...) is the
            // replica's typed answer; forward it
            Err(e) => return Err(e),
        };
        // expose the upstream location so a downstream cancel reaches
        // the replica that actually holds the task — and re-check the
        // flag to close the race where cancel arrived mid-submit
        *lock(&cell.upstream) = Some((client.clone(), raw.seq));
        if cell.canceled.load(Ordering::Relaxed) {
            client.send_wire_cancel(raw.seq);
        }
        handle.outstanding.fetch_add(1, Ordering::Relaxed);
        let outcome = pump_replies(&raw.rx, writer, seq);
        handle.outstanding.fetch_sub(1, Ordering::Relaxed);
        // `cell.upstream` still points at this replica here: the
        // DownstreamGone arm must forward the wire cancel through it
        // before it is cleared
        match outcome {
            PumpOutcome::DeliveredOk => {
                *lock(&cell.upstream) = None;
                return Ok(());
            }
            PumpOutcome::Failed(e) => {
                *lock(&cell.upstream) = None;
                let retryable = matches!(e, ServiceError::Dropped(_));
                if retryable {
                    handle.mark_down();
                    if cell.canceled.load(Ordering::Relaxed) {
                        return Err(ServiceError::Canceled);
                    }
                    if idempotent {
                        continue; // deadline budget re-checked on entry
                    }
                }
                return Err(e);
            }
            PumpOutcome::FramesThenLost => {
                // frames already reached the client; a retry would
                // duplicate them, so surface the loss as typed Dropped
                *lock(&cell.upstream) = None;
                handle.mark_down();
                return Err(ServiceError::Dropped(
                    "replica died mid-stream after frames were forwarded"
                        .to_string(),
                ));
            }
            PumpOutcome::DownstreamGone(e) => {
                // nobody is listening anymore; release the replica-side
                // task while `upstream` still names it, then report
                // canceled for the ledger
                cell.cancel();
                *lock(&cell.upstream) = None;
                return Err(e);
            }
        }
    }
    Err(ServiceError::Overloaded { retry_after: shared.cfg.retry_after })
}

enum PumpOutcome {
    /// `Done(Ok)` was forwarded downstream
    DeliveredOk,
    /// upstream finished with a typed error; no frames were forwarded
    Failed(ServiceError),
    /// upstream died after at least one frame went downstream
    FramesThenLost,
    /// the downstream write failed — the client connection is gone
    DownstreamGone(ServiceError),
}

/// Forward one upstream reply stream downstream until `Done`.
fn pump_replies(
    rx: &std::sync::mpsc::Receiver<ReplyMsg>, writer: &Arc<Mutex<Conn>>,
    seq: u64,
) -> PumpOutcome {
    let mut frames_forwarded = 0usize;
    loop {
        match rx.recv() {
            Ok(ReplyMsg::Frame(f)) => {
                if send(writer, &ServerMsg::Frame { seq, frame: f }).is_err() {
                    return PumpOutcome::DownstreamGone(
                        ServiceError::Canceled,
                    );
                }
                frames_forwarded += 1;
            }
            Ok(ReplyMsg::Done(Ok(reply))) => {
                return match send(writer, &ServerMsg::Done {
                    seq,
                    result: Ok(reply),
                }) {
                    Ok(()) => PumpOutcome::DeliveredOk,
                    Err(_) => PumpOutcome::DownstreamGone(
                        ServiceError::Canceled,
                    ),
                };
            }
            Ok(ReplyMsg::Done(Err(e))) => {
                return if frames_forwarded > 0
                    && matches!(e, ServiceError::Dropped(_))
                {
                    PumpOutcome::FramesThenLost
                } else {
                    PumpOutcome::Failed(e)
                };
            }
            Err(_) => {
                let e = ServiceError::Dropped(
                    "upstream reply channel closed".to_string(),
                );
                return if frames_forwarded > 0 {
                    PumpOutcome::FramesThenLost
                } else {
                    PumpOutcome::Failed(e)
                };
            }
        }
    }
}
