//! Length-prefixed, versioned wire frames.
//!
//! Layout (12-byte header, then payload):
//!
//! ```text
//!   0        4     5        8            12
//!   +--------+-----+--------+------------+----------------+
//!   | "GTPF" | ver | 3x0x00 | len (u32be)| UTF-8 JSON ... |
//!   +--------+-----+--------+------------+----------------+
//! ```
//!
//! Every failure mode is a typed [`WireError`]; a torn read is
//! `Truncated`, a clean close between frames is `Closed` — readers
//! never hang on a half-frame and never confuse the two.

use std::io::{ErrorKind, Read, Write};

use crate::util::failpoint;

/// Frame magic: "Gaunt Tensor Product Frame".
pub const MAGIC: [u8; 4] = *b"GTPF";
/// Current protocol version; bumped on incompatible frame or message
/// changes.  Negotiated in the Hello/HelloAck handshake.
pub const VERSION: u8 = 1;
/// Header bytes preceding every payload.
pub const HEADER_LEN: usize = 12;
/// Hard ceiling on a single payload (64 MiB) — a corrupt or hostile
/// length prefix must not let a reader allocate unbounded memory.
pub const MAX_FRAME_LEN: usize = 64 << 20;

/// Typed failure modes of the frame layer.
#[derive(Debug)]
pub enum WireError {
    /// Clean EOF on a frame boundary — the peer closed normally.
    Closed,
    /// Underlying socket error.
    Io(std::io::Error),
    /// First four bytes were not `GTPF` — not speaking our protocol.
    BadMagic([u8; 4]),
    /// Peer speaks an incompatible frame version.
    Version { got: u8, want: u8 },
    /// Length prefix exceeds [`MAX_FRAME_LEN`].
    TooLarge { len: usize },
    /// EOF mid-frame: got fewer bytes than the header promised.
    Truncated { got: usize, want: usize },
    /// Payload failed to decode (bad UTF-8, bad JSON, bad message
    /// shape).  Carries a human-readable reason.
    Codec(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Closed => write!(f, "connection closed"),
            WireError::Io(e) => write!(f, "socket error: {e}"),
            WireError::BadMagic(m) => {
                write!(f, "bad frame magic {m:?} (want {MAGIC:?})")
            }
            WireError::Version { got, want } => {
                write!(f, "protocol version mismatch: got {got}, want {want}")
            }
            WireError::TooLarge { len } => write!(
                f,
                "frame length {len} exceeds cap {MAX_FRAME_LEN}"
            ),
            WireError::Truncated { got, want } => {
                write!(f, "truncated frame: got {got} of {want} bytes")
            }
            WireError::Codec(m) => write!(f, "codec error: {m}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        WireError::Io(e)
    }
}

/// Write one frame (header + payload) and flush.
pub fn write_frame<W: Write>(w: &mut W, payload: &str) -> Result<(), WireError> {
    let bytes = payload.as_bytes();
    if bytes.len() > MAX_FRAME_LEN {
        return Err(WireError::TooLarge { len: bytes.len() });
    }
    let mut header = [0u8; HEADER_LEN];
    header[..4].copy_from_slice(&MAGIC);
    header[4] = VERSION;
    header[8..12].copy_from_slice(&(bytes.len() as u32).to_be_bytes());
    // one buffered write so small frames go out as a single segment
    let mut buf = Vec::with_capacity(HEADER_LEN + bytes.len());
    buf.extend_from_slice(&header);
    buf.extend_from_slice(bytes);
    w.write_all(&buf)?;
    w.flush()?;
    Ok(())
}

/// Read exactly `buf.len()` bytes; distinguishes clean EOF at offset 0
/// (`Closed` if `at_boundary`) from EOF mid-read (`Truncated`).
fn read_exact_or(
    r: &mut impl Read, buf: &mut [u8], at_boundary: bool, want_total: usize,
    already: usize,
) -> Result<(), WireError> {
    let mut filled = 0usize;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                return if at_boundary && filled == 0 && already == 0 {
                    Err(WireError::Closed)
                } else {
                    Err(WireError::Truncated {
                        got: already + filled,
                        want: want_total,
                    })
                };
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    Ok(())
}

/// Read one frame, returning the payload string.
///
/// Failpoint `net.read_frame` (chaos suite): an `error` policy surfaces
/// as `WireError::Codec` — the torn-frame simulation the conformance
/// tests use to prove a protocol error is typed, not a deadlock.
pub fn read_frame<R: Read>(r: &mut R) -> Result<String, WireError> {
    if let Some(fault) = failpoint::check("net.read_frame") {
        match fault {
            failpoint::Fault::Error(m) => {
                return Err(WireError::Codec(format!(
                    "injected torn frame: {m}"
                )))
            }
            failpoint::Fault::Nan => {}
        }
    }
    let mut header = [0u8; HEADER_LEN];
    read_exact_or(r, &mut header, true, HEADER_LEN, 0)?;
    if header[..4] != MAGIC {
        let mut m = [0u8; 4];
        m.copy_from_slice(&header[..4]);
        return Err(WireError::BadMagic(m));
    }
    if header[4] != VERSION {
        return Err(WireError::Version {
            got: header[4],
            want: VERSION,
        });
    }
    let len = u32::from_be_bytes([header[8], header[9], header[10], header[11]])
        as usize;
    if len > MAX_FRAME_LEN {
        return Err(WireError::TooLarge { len });
    }
    let mut payload = vec![0u8; len];
    read_exact_or(r, &mut payload, false, HEADER_LEN + len, HEADER_LEN)?;
    String::from_utf8(payload)
        .map_err(|e| WireError::Codec(format!("payload is not UTF-8: {e}")))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn encode(payload: &str) -> Vec<u8> {
        let mut buf = Vec::new();
        write_frame(&mut buf, payload).unwrap();
        buf
    }

    #[test]
    fn roundtrip() {
        for payload in ["", "x", "{\"k\":[1,2,3]}", &"y".repeat(100_000)] {
            let buf = encode(payload);
            assert_eq!(buf.len(), HEADER_LEN + payload.len());
            let got = read_frame(&mut Cursor::new(&buf)).unwrap();
            assert_eq!(got, payload);
        }
    }

    #[test]
    fn several_frames_back_to_back() {
        let mut buf = Vec::new();
        write_frame(&mut buf, "one").unwrap();
        write_frame(&mut buf, "two").unwrap();
        let mut cur = Cursor::new(&buf);
        assert_eq!(read_frame(&mut cur).unwrap(), "one");
        assert_eq!(read_frame(&mut cur).unwrap(), "two");
        assert!(matches!(read_frame(&mut cur), Err(WireError::Closed)));
    }

    #[test]
    fn clean_eof_is_closed_torn_is_truncated() {
        // EOF exactly on the boundary
        assert!(matches!(
            read_frame(&mut Cursor::new(&[] as &[u8])),
            Err(WireError::Closed)
        ));
        // every proper prefix of a real frame is Truncated, never Closed
        let buf = encode("{\"seq\":1}");
        for cut in 1..buf.len() {
            match read_frame(&mut Cursor::new(&buf[..cut])) {
                Err(WireError::Truncated { got, want }) => {
                    assert_eq!(got, cut);
                    assert!(want == HEADER_LEN || want == buf.len());
                }
                other => panic!("cut={cut}: expected Truncated, got {other:?}"),
            }
        }
    }

    #[test]
    fn bad_magic_and_version_are_typed() {
        let mut buf = encode("hi");
        buf[0] = b'X';
        assert!(matches!(
            read_frame(&mut Cursor::new(&buf)),
            Err(WireError::BadMagic(_))
        ));
        let mut buf = encode("hi");
        buf[4] = 9;
        assert!(matches!(
            read_frame(&mut Cursor::new(&buf)),
            Err(WireError::Version { got: 9, want: VERSION })
        ));
    }

    #[test]
    fn oversize_length_prefix_is_rejected_without_allocating() {
        let mut buf = encode("hi");
        buf[8..12].copy_from_slice(&u32::MAX.to_be_bytes());
        assert!(matches!(
            read_frame(&mut Cursor::new(&buf)),
            Err(WireError::TooLarge { .. })
        ));
    }

    #[test]
    fn non_utf8_payload_is_a_codec_error() {
        let mut buf = encode("ab");
        let n = buf.len();
        buf[n - 1] = 0xFF;
        buf[n - 2] = 0xFE;
        assert!(matches!(
            read_frame(&mut Cursor::new(&buf)),
            Err(WireError::Codec(_))
        ));
    }
}
