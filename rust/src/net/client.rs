//! The socket client: the in-process `Client` API, over a wire.
//!
//! [`NetClient::submit`] takes the same typed [`Request`] the
//! in-process client takes and returns a [`NetTicket`] with the same
//! surface (`wait` / `try_poll` / `next_frame` / `cancel`), so serving
//! code is source-compatible across deployment shapes.  Under the hood
//! a reader thread demultiplexes `Frame`/`Done` messages into
//! per-submission channels via [`ReplySlot`] — which carries the
//! reply-on-drop guarantee across the process boundary: if the
//! connection dies, every in-flight ticket resolves to a typed error,
//! never a hang.

use std::collections::HashMap;
use std::collections::VecDeque;
use std::marker::PhantomData;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use crate::coordinator::request::{ReplyMsg, ReplySlot};
use crate::coordinator::{
    Frame, HealthState, MetricsSnapshot, Reply, Request, ServiceError, Task,
    TaskSpec,
};

use super::frame::{read_frame, write_frame, VERSION};
use super::proto::{encode_client, decode_server, ClientMsg, ServerMsg};
use super::{Addr, Conn};

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Handshake read budget: a server that accepts but never answers Hello
/// must fail `connect`, not hang it.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(5);

struct Shared {
    /// write half; `None` once the connection is closed or dead
    writer: Mutex<Option<Conn>>,
    /// a second handle onto the socket, kept only to force-unblock the
    /// reader thread on close
    breaker: Mutex<Option<Conn>>,
    pending: Mutex<HashMap<u64, ReplySlot>>,
    next_seq: AtomicU64,
    dead: AtomicBool,
    /// FIFO queues of probe waiters: the server answers pings/stats in
    /// request order on this one ordered connection, so concurrent
    /// callers correlate by position — a single slot would let a second
    /// caller overwrite the first's sender
    pong_waiters: Mutex<VecDeque<Sender<(HealthState, usize)>>>,
    stats_waiters: Mutex<VecDeque<Sender<MetricsSnapshot>>>,
}

impl Shared {
    /// Encode + frame + send one message.  A failed write poisons the
    /// connection (the reader teardown then fails all pending tickets).
    fn send(&self, msg: &ClientMsg) -> Result<(), String> {
        let mut w = lock(&self.writer);
        let conn = w.as_mut().ok_or("connection closed")?;
        match write_frame(conn, &encode_client(msg)) {
            Ok(()) => Ok(()),
            Err(e) => {
                self.dead.store(true, Ordering::Relaxed);
                if let Some(c) = w.take() {
                    c.shutdown_both();
                }
                Err(e.to_string())
            }
        }
    }

    fn send_cancel(&self, seq: u64) {
        let _ = self.send(&ClientMsg::Cancel { seq });
    }

    /// Fail every in-flight submission with `err` and mark the
    /// connection dead.  Idempotent.
    fn teardown(&self, err: ServiceError) {
        self.dead.store(true, Ordering::Relaxed);
        if let Some(c) = lock(&self.writer).take() {
            c.shutdown_both();
        }
        lock(&self.breaker).take();
        let slots: Vec<ReplySlot> =
            lock(&self.pending).drain().map(|(_, s)| s).collect();
        for mut slot in slots {
            slot.finish(Err(err.clone()));
        }
        // dropping the senders fails blocked probe waiters with
        // Disconnected — a truthful "connection died"
        lock(&self.pong_waiters).clear();
        lock(&self.stats_waiters).clear();
    }
}

/// A connected socket client (one connection, many concurrent
/// submissions).
pub struct NetClient {
    shared: Arc<Shared>,
    reader: Mutex<Option<std::thread::JoinHandle<()>>>,
    max_atoms: usize,
    buckets: Vec<usize>,
}

impl NetClient {
    /// Connect and handshake with a default client name.
    pub fn connect(addr: &Addr) -> Result<NetClient, String> {
        NetClient::connect_named(addr, "net-client")
    }

    /// Connect, exchange `Hello`/`HelloAck`, and start the reader
    /// thread.
    pub fn connect_named(addr: &Addr, name: &str) -> Result<NetClient, String> {
        let mut conn =
            Conn::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
        let _ = conn.set_read_timeout(Some(HANDSHAKE_TIMEOUT));
        write_frame(
            &mut conn,
            &encode_client(&ClientMsg::Hello {
                version: VERSION as u64,
                name: name.to_string(),
            }),
        )
        .map_err(|e| format!("handshake send: {e}"))?;
        let ack = read_frame(&mut conn)
            .and_then(|p| decode_server(&p))
            .map_err(|e| format!("handshake recv: {e}"))?;
        let (max_atoms, buckets) = match ack {
            ServerMsg::HelloAck { version, max_atoms, buckets } => {
                if version != VERSION as u64 {
                    return Err(format!(
                        "server speaks protocol v{version}, client v{VERSION}"
                    ));
                }
                (max_atoms, buckets)
            }
            other => {
                return Err(format!("expected hello_ack, got {other:?}"))
            }
        };
        let _ = conn.set_read_timeout(None);

        let reader_conn =
            conn.try_clone().map_err(|e| format!("clone socket: {e}"))?;
        let breaker =
            conn.try_clone().map_err(|e| format!("clone socket: {e}"))?;
        let shared = Arc::new(Shared {
            writer: Mutex::new(Some(conn)),
            breaker: Mutex::new(Some(breaker)),
            pending: Mutex::new(HashMap::new()),
            next_seq: AtomicU64::new(1),
            dead: AtomicBool::new(false),
            pong_waiters: Mutex::new(VecDeque::new()),
            stats_waiters: Mutex::new(VecDeque::new()),
        });
        let reader = {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("net-client-reader".to_string())
                .spawn(move || reader_loop(reader_conn, shared))
                .map_err(|e| format!("spawn reader: {e}"))?
        };
        Ok(NetClient {
            shared,
            reader: Mutex::new(Some(reader)),
            max_atoms,
            buckets,
        })
    }

    /// Largest structure the server admits (from the handshake).
    pub fn max_atoms(&self) -> usize {
        self.max_atoms
    }

    /// The server's shape-bucket widths (from the handshake).
    pub fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    /// The connection is known broken; every call will fail fast.
    pub fn is_dead(&self) -> bool {
        self.shared.dead.load(Ordering::Relaxed)
    }

    /// Submit an untyped task — the front door's path (it routes
    /// [`Task`] values without knowing the client-side `TaskSpec`).
    pub fn submit_task(
        &self, task: Task, deadline_ms: Option<u64>, model: Option<String>,
    ) -> Result<RawNetTicket, ServiceError> {
        if self.is_dead() {
            return Err(ServiceError::Dropped(
                "connection is dead".to_string(),
            ));
        }
        // fail malformed/oversized work without a round trip, exactly
        // like the in-process client's submit path
        task.validate().map_err(ServiceError::Rejected)?;
        if task.n_atoms_max() > self.max_atoms {
            return Err(ServiceError::Rejected(format!(
                "structure of {} atoms exceeds the server's largest \
                 bucket ({} atoms)",
                task.n_atoms_max(),
                self.max_atoms
            )));
        }
        let seq = self.shared.next_seq.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        // register BEFORE sending: a reply can race back before the
        // submit call returns
        lock(&self.shared.pending).insert(seq, ReplySlot::new(tx));
        let msg = ClientMsg::Submit { seq, deadline_ms, model, task };
        if let Err(e) = self.shared.send(&msg) {
            // the insert above turns into a phantom entry; remove it so
            // teardown doesn't double-finish
            if let Some(mut slot) = lock(&self.shared.pending).remove(&seq) {
                slot.finish(Err(ServiceError::Dropped(e.clone())));
            }
            return Err(ServiceError::Dropped(e));
        }
        Ok(RawNetTicket { seq, rx, shared: self.shared.clone() })
    }

    /// Submit a typed request — source-compatible with the in-process
    /// `Client::submit`.
    pub fn submit<T: TaskSpec>(
        &self, req: Request<T>,
    ) -> Result<NetTicket<T>, ServiceError> {
        let Request { payload, deadline, model } = req;
        let deadline_ms = deadline.map(|d| (d.as_millis() as u64).max(1));
        let raw = self.submit_task(payload.into_task(), deadline_ms, model)?;
        Ok(NetTicket::from_raw(raw))
    }

    /// Health probe: the server's admission state + queue depth.
    pub fn ping(
        &self, timeout: Duration,
    ) -> Result<(HealthState, usize), String> {
        if self.is_dead() {
            return Err("connection is dead".to_string());
        }
        let (tx, rx) = channel();
        // enqueue BEFORE sending so the reply can't race the waiter in;
        // on timeout the entry stays queued — the late pong still pops
        // it (positional correlation) and its dead receiver eats it
        lock(&self.shared.pong_waiters).push_back(tx);
        self.shared.send(&ClientMsg::Ping)?;
        match rx.recv_timeout(timeout) {
            Ok(p) => Ok(p),
            Err(RecvTimeoutError::Timeout) => {
                Err("ping timed out".to_string())
            }
            Err(RecvTimeoutError::Disconnected) => {
                Err("connection died during ping".to_string())
            }
        }
    }

    /// Fetch the server's metrics ledger.
    pub fn stats(&self, timeout: Duration) -> Result<MetricsSnapshot, String> {
        if self.is_dead() {
            return Err("connection is dead".to_string());
        }
        let (tx, rx) = channel();
        lock(&self.shared.stats_waiters).push_back(tx);
        self.shared.send(&ClientMsg::Stats)?;
        match rx.recv_timeout(timeout) {
            Ok(s) => Ok(s),
            Err(RecvTimeoutError::Timeout) => {
                Err("stats timed out".to_string())
            }
            Err(RecvTimeoutError::Disconnected) => {
                Err("connection died during stats".to_string())
            }
        }
    }

    /// Ask the server to stop admitting new work.
    pub fn drain(&self) -> Result<(), String> {
        self.shared.send(&ClientMsg::Drain)
    }

    /// Send a wire cancel for an in-flight submission by sequence
    /// number — the front door's path when a downstream cancel has to
    /// chase a task that moved upstream.
    pub(crate) fn send_wire_cancel(&self, seq: u64) {
        self.shared.send_cancel(seq);
    }

    /// Graceful goodbye: in-flight tickets resolve to a typed error,
    /// the reader thread is joined.
    pub fn close(&self) {
        let _ = self.shared.send(&ClientMsg::Bye);
        self.shared.teardown(ServiceError::Dropped(
            "client closed the connection".to_string(),
        ));
        let handle = lock(&self.reader).take();
        if let Some(h) = handle {
            let _ = h.join();
        }
    }
}

impl Drop for NetClient {
    fn drop(&mut self) {
        self.close();
    }
}

fn reader_loop(mut conn: Conn, shared: Arc<Shared>) {
    loop {
        let payload = match read_frame(&mut conn) {
            Ok(p) => p,
            Err(e) => {
                // typed teardown: protocol damage is distinguishable
                // from the peer dying
                let err = match e {
                    super::frame::WireError::Closed => ServiceError::Dropped(
                        "server closed the connection".to_string(),
                    ),
                    super::frame::WireError::Io(ioe) => ServiceError::Dropped(
                        format!("connection lost: {ioe}"),
                    ),
                    other => ServiceError::Protocol(other.to_string()),
                };
                shared.teardown(err);
                return;
            }
        };
        let msg = match decode_server(&payload) {
            Ok(m) => m,
            Err(e) => {
                shared.teardown(ServiceError::Protocol(e.to_string()));
                return;
            }
        };
        match msg {
            ServerMsg::Frame { seq, frame } => {
                if let Some(slot) = lock(&shared.pending).get(&seq) {
                    slot.frame(frame);
                }
            }
            ServerMsg::Done { seq, result } => {
                if let Some(mut slot) = lock(&shared.pending).remove(&seq) {
                    slot.finish(result);
                }
            }
            ServerMsg::Pong { health, queue_depth } => {
                if let Some(tx) = lock(&shared.pong_waiters).pop_front() {
                    let _ = tx.send((health, queue_depth));
                }
            }
            ServerMsg::StatsAck { metrics } => {
                if let Some(tx) = lock(&shared.stats_waiters).pop_front() {
                    let _ = tx.send(metrics);
                }
            }
            ServerMsg::HelloAck { .. } => {
                // a second handshake ack is a server bug; ignore it
            }
        }
    }
}

// ---------------------------------------------------------------------
// tickets
// ---------------------------------------------------------------------

/// The untyped wire ticket: the front door pumps these without knowing
/// the originating `TaskSpec`.  [`RawNetTicket::cancel`] sends a wire
/// `cancel`; dropping does NOT cancel (the owner decides).
pub struct RawNetTicket {
    pub seq: u64,
    pub rx: Receiver<ReplyMsg>,
    shared: Arc<Shared>,
}

impl RawNetTicket {
    /// Request cooperative cancellation on the server.
    pub fn cancel(&self) {
        self.shared.send_cancel(self.seq);
    }
}

/// The typed handle for one wire submission — same shape as the
/// in-process `Ticket`: `wait` blocks for the typed output, `try_poll`
/// polls, `next_frame` streams, `cancel`/drop release the server-side
/// task.
pub struct NetTicket<T: TaskSpec> {
    raw: RawNetTicket,
    frames: VecDeque<Frame>,
    done: Option<Result<Reply, ServiceError>>,
    delivered: bool,
    _spec: PhantomData<fn() -> T>,
}

impl<T: TaskSpec> NetTicket<T> {
    pub fn from_raw(raw: RawNetTicket) -> NetTicket<T> {
        NetTicket {
            raw,
            frames: VecDeque::new(),
            done: None,
            delivered: false,
            _spec: PhantomData,
        }
    }

    pub fn seq(&self) -> u64 {
        self.raw.seq
    }

    /// Request cooperative cancellation on the server; the final reply
    /// becomes `Canceled` unless the task already finished.
    pub fn cancel(&self) {
        self.raw.cancel();
    }

    fn absorb(&mut self, msg: ReplyMsg) {
        match msg {
            ReplyMsg::Frame(f) => self.frames.push_back(f),
            ReplyMsg::Done(r) => self.done = Some(r),
        }
    }

    fn disconnected(&mut self) {
        if self.done.is_none() {
            self.done = Some(Err(ServiceError::Dropped(
                "reply channel closed without a final message".to_string(),
            )));
        }
    }

    /// Block for the final reply and decode it into the task's typed
    /// output.  Never hangs: connection teardown fails every pending
    /// slot with a typed error.
    pub fn wait(mut self) -> Result<T::Output, ServiceError> {
        while self.done.is_none() {
            match self.raw.rx.recv() {
                Ok(msg) => self.absorb(msg),
                Err(_) => self.disconnected(),
            }
        }
        // mark delivered so Drop doesn't fire a spurious wire cancel
        self.delivered = true;
        match self.done.take().unwrap() {
            Ok(r) => {
                T::decode(r, Vec::from(std::mem::take(&mut self.frames)))
            }
            Err(e) => Err(e),
        }
    }

    /// Non-blocking poll: `Some(result)` exactly once when done.
    pub fn try_poll(&mut self) -> Option<Result<T::Output, ServiceError>> {
        if self.delivered {
            return None;
        }
        loop {
            match self.raw.rx.try_recv() {
                Ok(msg) => self.absorb(msg),
                Err(std::sync::mpsc::TryRecvError::Empty) => break,
                Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                    self.disconnected();
                    break;
                }
            }
        }
        let done = self.done.take()?;
        self.delivered = true;
        Some(match done {
            Ok(reply) => {
                T::decode(reply, Vec::from(std::mem::take(&mut self.frames)))
            }
            Err(e) => Err(e),
        })
    }

    /// Blocking frame stream; `None` once the final reply arrived.
    pub fn next_frame(&mut self) -> Option<Frame> {
        if let Some(f) = self.frames.pop_front() {
            return Some(f);
        }
        if self.done.is_some() || self.delivered {
            return None;
        }
        loop {
            match self.raw.rx.recv() {
                Ok(ReplyMsg::Frame(f)) => return Some(f),
                Ok(ReplyMsg::Done(r)) => {
                    self.done = Some(r);
                    return None;
                }
                Err(_) => {
                    self.disconnected();
                    return None;
                }
            }
        }
    }
}

impl<T: TaskSpec> Drop for NetTicket<T> {
    fn drop(&mut self) {
        // an abandoned in-flight ticket releases the server-side task;
        // finished or delivered tickets don't send a stale cancel
        if !self.delivered && self.done.is_none() {
            self.raw.cancel();
        }
    }
}
