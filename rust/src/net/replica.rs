//! The replica: one `coordinator::Service` behind TCP and/or
//! Unix-domain listeners.
//!
//! Each connection gets a handler thread (blocking reads) plus one
//! lightweight forwarder thread per in-flight submission, pumping the
//! service's reply channel into wire frames.  The server-side contract
//! mirrors the in-process one:
//!
//! * every accepted `submit` gets exactly one `Done` (reply-on-drop
//!   travels through the forwarder);
//! * a wire `cancel` — or the connection dying, including a handler
//!   panic injected via the `net.replica.crash` failpoint — sets the
//!   cooperative cancel flag on every in-flight service ticket, so a
//!   disconnected client never leaves an orphaned relaxation or
//!   rollout burning a worker.

use std::collections::HashMap;
use std::io;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use crate::coordinator::request::ReplyMsg;
use crate::coordinator::service::{Client, Service};
use crate::coordinator::ServiceError;
use crate::util::failpoint;

use super::frame::{read_frame, write_frame, WireError, VERSION};
use super::proto::{decode_client, encode_server, ClientMsg, ServerMsg};
use super::{poke, spawn_acceptor, Addr, Conn, ConnRegistry, Listener};

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// A connection must say Hello within this budget or it is dropped —
/// an idle port-scanner can't pin a handler thread forever.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(5);

/// A serving replica: the owned [`Service`] plus its listeners.
pub struct Replica {
    service: Option<Service>,
    client: Client,
    stop: Arc<AtomicBool>,
    bound: Vec<Addr>,
    acceptors: Vec<std::thread::JoinHandle<()>>,
    conns: Arc<ConnRegistry>,
}

impl Replica {
    /// Bind every address and start serving `service`.  Returns once
    /// the listeners are live; the actual bound addresses (TCP port 0
    /// resolved) are in [`Replica::bound`].
    pub fn serve(
        service: Service, addrs: &[Addr], name: &str,
    ) -> io::Result<Replica> {
        let client = service.client();
        let stop = Arc::new(AtomicBool::new(false));
        let conns = Arc::new(ConnRegistry::new());
        let mut bound = Vec::new();
        let mut acceptors = Vec::new();
        for addr in addrs {
            let (listener, actual) = Listener::bind(addr)?;
            let handler: Arc<dyn Fn(Conn) + Send + Sync> = {
                let client = client.clone();
                let conns = conns.clone();
                Arc::new(move |conn: Conn| {
                    handle_conn(conn, client.clone(), conns.clone())
                })
            };
            acceptors.push(spawn_acceptor(
                listener,
                stop.clone(),
                format!("replica-{name}"),
                handler,
            ));
            bound.push(actual);
        }
        Ok(Replica {
            service: Some(service),
            client,
            stop,
            bound,
            acceptors,
            conns,
        })
    }

    /// The addresses actually bound (TCP port 0 resolved to the
    /// kernel-assigned port).
    pub fn bound(&self) -> &[Addr] {
        &self.bound
    }

    /// An in-process submission handle onto the served service — what
    /// the conformance tests use to observe server-side effects
    /// (canceled counters, queue depth) of wire activity.
    pub fn client(&self) -> Client {
        self.client.clone()
    }

    /// Stop admitting new work; queued work keeps executing.
    pub fn drain(&self) {
        self.client.drain();
    }

    /// Stop accepting, sever every live connection (in-flight wire
    /// tickets resolve via reply-on-drop), then shut the service down.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        for addr in &self.bound {
            poke(addr);
        }
        for h in self.acceptors.drain(..) {
            let _ = h.join();
        }
        self.conns.sever_all();
        if let Some(service) = self.service.take() {
            service.shutdown();
        }
        // unbound unix socket files should not litter the filesystem
        for addr in &self.bound {
            if let Addr::Unix(p) = addr {
                let _ = std::fs::remove_file(p);
            }
        }
    }
}

/// Per-connection state: one handler thread, many forwarders.
fn handle_conn(conn: Conn, client: Client, conns: Arc<ConnRegistry>) {
    // register a handle for Replica::shutdown to sever; deregistered
    // below so a long-lived replica doesn't leak one fd per connection
    let reg = conns.register(&conn);
    let teardown_conn = match conn.try_clone() {
        Ok(c) => c,
        Err(_) => {
            conns.deregister(reg);
            conn.shutdown_both();
            return;
        }
    };
    // every in-flight submission's cooperative cancel flag, keyed by
    // wire seq — the one structure teardown needs
    let inflight: Arc<Mutex<HashMap<u64, Arc<AtomicBool>>>> =
        Arc::new(Mutex::new(HashMap::new()));
    let result = catch_unwind(AssertUnwindSafe(|| {
        conn_loop(conn, &client, &inflight)
    }));
    // teardown runs whether the loop exited cleanly, errored, or
    // panicked (net.replica.crash): release every in-flight service
    // ticket so a dead connection cannot orphan long tasks
    for (_, cancel) in lock(&inflight).drain() {
        cancel.store(true, Ordering::Relaxed);
    }
    teardown_conn.shutdown_both();
    conns.deregister(reg);
    if result.is_err() {
        // the panic already printed; the connection died with it
    }
}

fn conn_loop(
    mut conn: Conn, client: &Client,
    inflight: &Arc<Mutex<HashMap<u64, Arc<AtomicBool>>>>,
) {
    // -------- handshake --------
    let _ = conn.set_read_timeout(Some(HANDSHAKE_TIMEOUT));
    let hello = match read_frame(&mut conn).and_then(|p| decode_client(&p)) {
        Ok(ClientMsg::Hello { version, name: _ }) => version,
        _ => return,
    };
    let writer = match conn.try_clone() {
        Ok(c) => Arc::new(Mutex::new(c)),
        Err(_) => return,
    };
    if hello != VERSION as u64 {
        // answer with our version so the client can report the
        // mismatch, then hang up
        let _ = send(&writer, &ServerMsg::HelloAck {
            version: VERSION as u64,
            max_atoms: 0,
            buckets: Vec::new(),
        });
        return;
    }
    if send(&writer, &ServerMsg::HelloAck {
        version: VERSION as u64,
        max_atoms: client.max_atoms(),
        buckets: client.bucket_widths(),
    })
    .is_err()
    {
        return;
    }
    let _ = conn.set_read_timeout(None);

    // -------- message loop --------
    loop {
        let msg = match read_frame(&mut conn) {
            Ok(p) => match decode_client(&p) {
                Ok(m) => m,
                // a malformed payload is a protocol violation; there is
                // no seq to correlate an error to, so hang up (the
                // client surfaces a typed teardown)
                Err(_) => return,
            },
            Err(WireError::Closed) => return,
            Err(_) => return,
        };
        match msg {
            ClientMsg::Submit { seq, deadline_ms, model, task } => {
                // chaos site: a `panic` policy here simulates the
                // replica crashing mid-submit — before the task is
                // enqueued, so the failure is clean from the service's
                // point of view and the front door can safely retry
                if let Some(failpoint::Fault::Error(_)) =
                    failpoint::check("net.replica.crash")
                {
                    return;
                }
                // a seq already in flight belongs to another
                // submission; admitting the duplicate would orphan the
                // original's cancel flag (whichever forwarder finishes
                // first removes the shared entry).  Ignore it — a Done
                // reply would finish the original's client-side slot.
                if lock(inflight).contains_key(&seq) {
                    continue;
                }
                let deadline = deadline_ms.map(Duration::from_millis);
                match client.submit_task(task, deadline, model) {
                    Ok(raw) => {
                        lock(inflight).insert(seq, raw.cancel.clone());
                        spawn_forwarder(
                            seq,
                            raw.rx,
                            raw.cancel,
                            writer.clone(),
                            inflight.clone(),
                        );
                    }
                    Err(e) => {
                        if send(&writer, &ServerMsg::Done {
                            seq,
                            result: Err(e),
                        })
                        .is_err()
                        {
                            return;
                        }
                    }
                }
            }
            ClientMsg::Cancel { seq } => {
                if let Some(flag) = lock(inflight).get(&seq) {
                    flag.store(true, Ordering::Relaxed);
                }
            }
            ClientMsg::Ping => {
                if send(&writer, &ServerMsg::Pong {
                    health: client.health(),
                    queue_depth: client.queue_depth(),
                })
                .is_err()
                {
                    return;
                }
            }
            ClientMsg::Stats => {
                if send(&writer, &ServerMsg::StatsAck {
                    metrics: client.metrics().snapshot(),
                })
                .is_err()
                {
                    return;
                }
            }
            ClientMsg::Drain => client.drain(),
            ClientMsg::Bye => return,
            ClientMsg::Hello { .. } => {
                // a second hello is a client bug; ignore it
            }
        }
    }
}

fn send(writer: &Arc<Mutex<Conn>>, msg: &ServerMsg) -> Result<(), WireError> {
    let mut w = lock(writer);
    write_frame(&mut *w, &encode_server(msg))
}

/// Pump one submission's reply channel into wire frames.  Exactly one
/// `Done` goes out per accepted submit (reply-on-drop upstream
/// guarantees the channel always ends with one); if the client becomes
/// unreachable mid-stream, the task is cooperatively canceled so it
/// stops burning worker time.
fn spawn_forwarder(
    seq: u64, rx: std::sync::mpsc::Receiver<ReplyMsg>,
    cancel: Arc<AtomicBool>, writer: Arc<Mutex<Conn>>,
    inflight: Arc<Mutex<HashMap<u64, Arc<AtomicBool>>>>,
) {
    let _ = std::thread::Builder::new()
        .name(format!("fwd-{seq}"))
        .spawn(move || {
            let mut client_gone = false;
            loop {
                match rx.recv() {
                    Ok(ReplyMsg::Frame(f)) => {
                        if client_gone {
                            continue; // draining to Done
                        }
                        if send(&writer, &ServerMsg::Frame { seq, frame: f })
                            .is_err()
                        {
                            client_gone = true;
                            cancel.store(true, Ordering::Relaxed);
                        }
                    }
                    Ok(ReplyMsg::Done(result)) => {
                        if !client_gone {
                            let _ = send(&writer, &ServerMsg::Done {
                                seq,
                                result,
                            });
                        }
                        break;
                    }
                    Err(_) => {
                        // channel died without Done — upstream
                        // reply-on-drop should make this unreachable,
                        // but the wire contract still holds
                        if !client_gone {
                            let _ = send(&writer, &ServerMsg::Done {
                                seq,
                                result: Err(ServiceError::Dropped(
                                    "reply channel closed without a final \
                                     message"
                                        .to_string(),
                                )),
                            });
                        }
                        break;
                    }
                }
            }
            lock(&inflight).remove(&seq);
        });
}
