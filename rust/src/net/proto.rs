//! Wire message shapes and their JSON codecs.
//!
//! Payloads are JSON via the hardened `util::json` parser
//! ([`crate::util::json::parse_limited`]) — zero new dependencies, and
//! a hostile peer can't stack-overflow or OOM the decoder.  Messages
//! are tagged objects (`{"type": "submit", ...}`); tasks and replies
//! are tagged by `"kind"`.  Positions travel as flat `[x0,y0,z0,...]`
//! arrays.  Decode failures are [`WireError::Codec`] with the exact
//! reason — the fuzz suite (`tests/json_fuzz.rs`) pins the no-panic
//! guarantee.
//!
//! Numbers ride on f64 (`Json::Num`); per-connection sequence numbers
//! start at 1 and stay far below the 2^53 integer-exactness bound.

use std::time::Duration;

use crate::coordinator::{
    EnergyOut, ExecFault, ForceResponse, Frame, HealthState,
    MetricsSnapshot, Reply, RolloutSummary, ServiceError, Structure, Task,
};
use crate::md::relax::RelaxResult;
use crate::util::json::{self, Json, Limits};

use super::frame::WireError;

// ---------------------------------------------------------------------
// message shapes
// ---------------------------------------------------------------------

/// Client -> server messages.
#[derive(Clone, Debug)]
pub enum ClientMsg {
    /// First frame on every connection: the protocol version the client
    /// speaks plus a display name for logs.
    Hello { version: u64, name: String },
    /// Submit one task.  `seq` is the per-connection correlation id the
    /// server echoes on `Frame`/`Done`; deadlines travel in-band as a
    /// relative budget in milliseconds (absolute instants don't survive
    /// crossing a process boundary).
    Submit {
        seq: u64,
        deadline_ms: Option<u64>,
        model: Option<String>,
        task: Task,
    },
    /// Cooperatively cancel an in-flight submission.
    Cancel { seq: u64 },
    /// Health probe; answered with [`ServerMsg::Pong`].
    Ping,
    /// Ask the server to stop admitting new work (graceful drain).
    Drain,
    /// Ask for the server's metrics ledger.
    Stats,
    /// Clean goodbye; the server closes the connection.
    Bye,
}

/// Server -> client messages.
#[derive(Clone, Debug)]
pub enum ServerMsg {
    /// Handshake answer: negotiated version plus serving shape info
    /// (largest admissible structure, bucket widths) so clients can
    /// reject oversized work without a round trip.
    HelloAck { version: u64, max_atoms: usize, buckets: Vec<usize> },
    /// One streamed MD frame for submission `seq`.
    Frame { seq: u64, frame: Frame },
    /// Final reply for submission `seq` — exactly one per accepted
    /// submit, mirroring the in-process reply-on-drop guarantee.
    Done { seq: u64, result: Result<Reply, ServiceError> },
    /// Health probe answer; `health` makes the admission state
    /// (healthy / shedding / draining) wire-visible.
    Pong { health: HealthState, queue_depth: usize },
    /// Metrics ledger answer.
    StatsAck { metrics: MetricsSnapshot },
}

// ---------------------------------------------------------------------
// field helpers (Result<_, String>; one Codec mapping at the top)
// ---------------------------------------------------------------------

fn need<'a>(v: &'a Json, key: &str) -> Result<&'a Json, String> {
    v.get(key).ok_or_else(|| format!("missing field '{key}'"))
}

fn need_f64(v: &Json, key: &str) -> Result<f64, String> {
    need(v, key)?
        .as_f64()
        .ok_or_else(|| format!("field '{key}' is not a number"))
}

fn need_u64(v: &Json, key: &str) -> Result<u64, String> {
    let n = need_f64(v, key)?;
    if !n.is_finite() || n < 0.0 || n != n.trunc() {
        return Err(format!("field '{key}' is not a non-negative integer"));
    }
    Ok(n as u64)
}

fn need_usize(v: &Json, key: &str) -> Result<usize, String> {
    Ok(need_u64(v, key)? as usize)
}

fn need_str<'a>(v: &'a Json, key: &str) -> Result<&'a str, String> {
    need(v, key)?
        .as_str()
        .ok_or_else(|| format!("field '{key}' is not a string"))
}

fn need_bool(v: &Json, key: &str) -> Result<bool, String> {
    need(v, key)?
        .as_bool()
        .ok_or_else(|| format!("field '{key}' is not a bool"))
}

fn f64_list(v: &Json, key: &str) -> Result<Vec<f64>, String> {
    let arr = need(v, key)?
        .as_arr()
        .ok_or_else(|| format!("field '{key}' is not an array"))?;
    arr.iter()
        .map(|x| x.as_f64().ok_or_else(|| format!("non-number in '{key}'")))
        .collect()
}

fn pos_to_json(pos: &[[f64; 3]]) -> Json {
    let mut flat = Vec::with_capacity(pos.len() * 3);
    for p in pos {
        flat.extend_from_slice(p);
    }
    Json::arr_f64(&flat)
}

fn pos_from_json(v: &Json, key: &str) -> Result<Vec<[f64; 3]>, String> {
    let flat = f64_list(v, key)?;
    if flat.len() % 3 != 0 {
        return Err(format!(
            "field '{key}' has {} values, not a multiple of 3",
            flat.len()
        ));
    }
    Ok(flat.chunks_exact(3).map(|c| [c[0], c[1], c[2]]).collect())
}

// ---------------------------------------------------------------------
// structures + tasks
// ---------------------------------------------------------------------

fn structure_to_json(st: &Structure) -> Json {
    let species: Vec<f64> = st.species.iter().map(|&s| s as f64).collect();
    Json::obj(vec![
        ("pos", pos_to_json(&st.pos)),
        ("species", Json::arr_f64(&species)),
    ])
}

fn structure_from_json(v: &Json) -> Result<Structure, String> {
    let pos = pos_from_json(v, "pos")?;
    let species = f64_list(v, "species")?
        .into_iter()
        .map(|s| {
            if s.is_finite() && s >= 0.0 && s == s.trunc() {
                Ok(s as usize)
            } else {
                Err(format!("bad species value {s}"))
            }
        })
        .collect::<Result<Vec<usize>, String>>()?;
    Ok(Structure { pos, species })
}

pub fn task_to_json(t: &Task) -> Json {
    match t {
        Task::EnergyOnly { structure } => Json::obj(vec![
            ("kind", Json::Str("energy".into())),
            ("structure", structure_to_json(structure)),
        ]),
        Task::EnergyForces { structure } => Json::obj(vec![
            ("kind", Json::Str("energy_forces".into())),
            ("structure", structure_to_json(structure)),
        ]),
        Task::Relax { structure, max_steps } => Json::obj(vec![
            ("kind", Json::Str("relax".into())),
            ("structure", structure_to_json(structure)),
            ("max_steps", Json::Num(*max_steps as f64)),
        ]),
        Task::MdRollout { structure, steps, dt } => Json::obj(vec![
            ("kind", Json::Str("md_rollout".into())),
            ("structure", structure_to_json(structure)),
            ("steps", Json::Num(*steps as f64)),
            ("dt", Json::Num(*dt)),
        ]),
        Task::Batch { structures } => Json::obj(vec![
            ("kind", Json::Str("batch".into())),
            (
                "structures",
                Json::Arr(structures.iter().map(structure_to_json).collect()),
            ),
        ]),
    }
}

pub fn task_from_json(v: &Json) -> Result<Task, String> {
    match need_str(v, "kind")? {
        "energy" => Ok(Task::EnergyOnly {
            structure: structure_from_json(need(v, "structure")?)?,
        }),
        "energy_forces" => Ok(Task::EnergyForces {
            structure: structure_from_json(need(v, "structure")?)?,
        }),
        "relax" => Ok(Task::Relax {
            structure: structure_from_json(need(v, "structure")?)?,
            max_steps: need_usize(v, "max_steps")?,
        }),
        "md_rollout" => {
            let dt = need_f64(v, "dt")?;
            Ok(Task::MdRollout {
                structure: structure_from_json(need(v, "structure")?)?,
                steps: need_usize(v, "steps")?,
                dt,
            })
        }
        "batch" => {
            let arr = need(v, "structures")?
                .as_arr()
                .ok_or("field 'structures' is not an array")?;
            Ok(Task::Batch {
                structures: arr
                    .iter()
                    .map(structure_from_json)
                    .collect::<Result<Vec<_>, _>>()?,
            })
        }
        other => Err(format!("unknown task kind '{other}'")),
    }
}

// ---------------------------------------------------------------------
// frames + replies
// ---------------------------------------------------------------------

fn frame_to_json(f: &Frame) -> Json {
    Json::obj(vec![
        ("step", Json::Num(f.step as f64)),
        ("time", Json::Num(f.time)),
        ("energy", Json::Num(f.energy)),
        ("kinetic", Json::Num(f.kinetic)),
        ("pos", pos_to_json(&f.pos)),
    ])
}

fn frame_from_json(v: &Json) -> Result<Frame, String> {
    Ok(Frame {
        step: need_usize(v, "step")?,
        time: need_f64(v, "time")?,
        energy: need_f64(v, "energy")?,
        kinetic: need_f64(v, "kinetic")?,
        pos: pos_from_json(v, "pos")?,
    })
}

fn force_response_to_json(r: &ForceResponse) -> Json {
    Json::obj(vec![
        ("id", Json::Num(r.id as f64)),
        ("energy", Json::Num(r.energy)),
        ("forces", pos_to_json(&r.forces)),
        ("latency_s", Json::Num(r.latency_s)),
    ])
}

fn force_response_from_json(v: &Json) -> Result<ForceResponse, String> {
    Ok(ForceResponse {
        id: need_u64(v, "id")?,
        energy: need_f64(v, "energy")?,
        forces: pos_from_json(v, "forces")?,
        latency_s: need_f64(v, "latency_s")?,
    })
}

fn reply_to_json(r: &Reply) -> Json {
    match r {
        Reply::Energy(e) => Json::obj(vec![
            ("kind", Json::Str("energy".into())),
            ("id", Json::Num(e.id as f64)),
            ("energy", Json::Num(e.energy)),
            ("latency_s", Json::Num(e.latency_s)),
        ]),
        Reply::EnergyForces(r) => {
            let mut j = force_response_to_json(r);
            if let Json::Obj(m) = &mut j {
                m.insert(
                    "kind".to_string(),
                    Json::Str("energy_forces".into()),
                );
            }
            j
        }
        Reply::Relaxed(r) => Json::obj(vec![
            ("kind", Json::Str("relaxed".into())),
            ("pos", pos_to_json(&r.pos)),
            ("energy", Json::Num(r.energy)),
            ("max_force", Json::Num(r.max_force)),
            ("steps", Json::Num(r.steps as f64)),
            ("converged", Json::Bool(r.converged)),
            ("energy_trace", Json::arr_f64(&r.energy_trace)),
        ]),
        Reply::Rollout(s) => Json::obj(vec![
            ("kind", Json::Str("rollout".into())),
            ("id", Json::Num(s.id as f64)),
            ("steps", Json::Num(s.steps as f64)),
            ("final_pos", pos_to_json(&s.final_pos)),
            ("final_energy", Json::Num(s.final_energy)),
        ]),
        Reply::Batch(rs) => Json::obj(vec![
            ("kind", Json::Str("batch".into())),
            (
                "items",
                Json::Arr(rs.iter().map(force_response_to_json).collect()),
            ),
        ]),
    }
}

fn reply_from_json(v: &Json) -> Result<Reply, String> {
    match need_str(v, "kind")? {
        "energy" => Ok(Reply::Energy(EnergyOut {
            id: need_u64(v, "id")?,
            energy: need_f64(v, "energy")?,
            latency_s: need_f64(v, "latency_s")?,
        })),
        "energy_forces" => {
            Ok(Reply::EnergyForces(force_response_from_json(v)?))
        }
        "relaxed" => Ok(Reply::Relaxed(RelaxResult {
            pos: pos_from_json(v, "pos")?,
            energy: need_f64(v, "energy")?,
            max_force: need_f64(v, "max_force")?,
            steps: need_usize(v, "steps")?,
            converged: need_bool(v, "converged")?,
            energy_trace: f64_list(v, "energy_trace")?,
        })),
        "rollout" => Ok(Reply::Rollout(RolloutSummary {
            id: need_u64(v, "id")?,
            steps: need_usize(v, "steps")?,
            final_pos: pos_from_json(v, "final_pos")?,
            final_energy: need_f64(v, "final_energy")?,
        })),
        "batch" => {
            let arr = need(v, "items")?
                .as_arr()
                .ok_or("field 'items' is not an array")?;
            Ok(Reply::Batch(
                arr.iter()
                    .map(force_response_from_json)
                    .collect::<Result<Vec<_>, _>>()?,
            ))
        }
        other => Err(format!("unknown reply kind '{other}'")),
    }
}

// ---------------------------------------------------------------------
// service errors
// ---------------------------------------------------------------------

fn error_to_json(e: &ServiceError) -> Json {
    let (code, msg, retry_after_ms): (&str, String, Option<f64>) = match e {
        ServiceError::Rejected(m) => ("rejected", m.clone(), None),
        ServiceError::Overloaded { retry_after } => (
            "overloaded",
            String::new(),
            Some(retry_after.as_secs_f64() * 1e3),
        ),
        ServiceError::DeadlineExceeded => {
            ("deadline", String::new(), None)
        }
        ServiceError::Canceled => ("canceled", String::new(), None),
        ServiceError::Shutdown => ("shutdown", String::new(), None),
        ServiceError::Dropped(m) => ("dropped", m.clone(), None),
        ServiceError::Exec(ExecFault::Backend(m)) => {
            ("exec_backend", m.clone(), None)
        }
        ServiceError::Exec(ExecFault::NonFinite(m)) => {
            ("exec_nonfinite", m.clone(), None)
        }
        ServiceError::Exec(ExecFault::BudgetExhausted(m)) => {
            ("exec_budget", m.clone(), None)
        }
        ServiceError::Protocol(m) => ("protocol", m.clone(), None),
    };
    let mut pairs = vec![
        ("code", Json::Str(code.to_string())),
        ("msg", Json::Str(msg)),
    ];
    if let Some(ms) = retry_after_ms {
        pairs.push(("retry_after_ms", Json::Num(ms)));
    }
    Json::obj(pairs)
}

fn error_from_json(v: &Json) -> Result<ServiceError, String> {
    let msg = need_str(v, "msg")?.to_string();
    match need_str(v, "code")? {
        "rejected" => Ok(ServiceError::Rejected(msg)),
        "overloaded" => {
            let ms = v
                .get("retry_after_ms")
                .and_then(Json::as_f64)
                .filter(|m| m.is_finite() && *m >= 0.0)
                .unwrap_or(50.0);
            Ok(ServiceError::Overloaded {
                retry_after: Duration::from_secs_f64(ms / 1e3),
            })
        }
        "deadline" => Ok(ServiceError::DeadlineExceeded),
        "canceled" => Ok(ServiceError::Canceled),
        "shutdown" => Ok(ServiceError::Shutdown),
        "dropped" => Ok(ServiceError::Dropped(msg)),
        "exec_backend" => {
            Ok(ServiceError::Exec(ExecFault::Backend(msg)))
        }
        "exec_nonfinite" => {
            Ok(ServiceError::Exec(ExecFault::NonFinite(msg)))
        }
        "exec_budget" => {
            Ok(ServiceError::Exec(ExecFault::BudgetExhausted(msg)))
        }
        "protocol" => Ok(ServiceError::Protocol(msg)),
        other => Err(format!("unknown error code '{other}'")),
    }
}

fn health_to_str(h: HealthState) -> &'static str {
    match h {
        HealthState::Healthy => "healthy",
        HealthState::Shedding => "shedding",
        HealthState::Draining => "draining",
    }
}

fn health_from_str(s: &str) -> Result<HealthState, String> {
    match s {
        "healthy" => Ok(HealthState::Healthy),
        "shedding" => Ok(HealthState::Shedding),
        "draining" => Ok(HealthState::Draining),
        other => Err(format!("unknown health state '{other}'")),
    }
}

// ---------------------------------------------------------------------
// top-level messages
// ---------------------------------------------------------------------

pub fn encode_client(m: &ClientMsg) -> String {
    let j = match m {
        ClientMsg::Hello { version, name } => Json::obj(vec![
            ("type", Json::Str("hello".into())),
            ("version", Json::Num(*version as f64)),
            ("name", Json::Str(name.clone())),
        ]),
        ClientMsg::Submit { seq, deadline_ms, model, task } => {
            let mut pairs = vec![
                ("type", Json::Str("submit".into())),
                ("seq", Json::Num(*seq as f64)),
                ("task", task_to_json(task)),
            ];
            if let Some(d) = deadline_ms {
                pairs.push(("deadline_ms", Json::Num(*d as f64)));
            }
            if let Some(name) = model {
                pairs.push(("model", Json::Str(name.clone())));
            }
            Json::obj(pairs)
        }
        ClientMsg::Cancel { seq } => Json::obj(vec![
            ("type", Json::Str("cancel".into())),
            ("seq", Json::Num(*seq as f64)),
        ]),
        ClientMsg::Ping => Json::obj(vec![("type", Json::Str("ping".into()))]),
        ClientMsg::Drain => {
            Json::obj(vec![("type", Json::Str("drain".into()))])
        }
        ClientMsg::Stats => {
            Json::obj(vec![("type", Json::Str("stats".into()))])
        }
        ClientMsg::Bye => Json::obj(vec![("type", Json::Str("bye".into()))]),
    };
    j.to_string()
}

pub fn decode_client(s: &str) -> Result<ClientMsg, WireError> {
    decode_client_json(s).map_err(WireError::Codec)
}

fn decode_client_json(s: &str) -> Result<ClientMsg, String> {
    let v = json::parse_limited(s, &Limits::default())
        .map_err(|e| e.to_string())?;
    match need_str(&v, "type")? {
        "hello" => Ok(ClientMsg::Hello {
            version: need_u64(&v, "version")?,
            name: need_str(&v, "name")?.to_string(),
        }),
        "submit" => {
            let deadline_ms = match v.get("deadline_ms") {
                None | Some(Json::Null) => None,
                Some(d) => {
                    let n = d
                        .as_f64()
                        .filter(|n| {
                            n.is_finite() && *n >= 0.0 && *n == n.trunc()
                        })
                        .ok_or("bad 'deadline_ms'")?;
                    Some(n as u64)
                }
            };
            let model = match v.get("model") {
                None | Some(Json::Null) => None,
                Some(m) => {
                    Some(m.as_str().ok_or("bad 'model'")?.to_string())
                }
            };
            Ok(ClientMsg::Submit {
                seq: need_u64(&v, "seq")?,
                deadline_ms,
                model,
                task: task_from_json(need(&v, "task")?)?,
            })
        }
        "cancel" => Ok(ClientMsg::Cancel { seq: need_u64(&v, "seq")? }),
        "ping" => Ok(ClientMsg::Ping),
        "drain" => Ok(ClientMsg::Drain),
        "stats" => Ok(ClientMsg::Stats),
        "bye" => Ok(ClientMsg::Bye),
        other => Err(format!("unknown client message type '{other}'")),
    }
}

pub fn encode_server(m: &ServerMsg) -> String {
    let j = match m {
        ServerMsg::HelloAck { version, max_atoms, buckets } => {
            let b: Vec<f64> = buckets.iter().map(|&x| x as f64).collect();
            Json::obj(vec![
                ("type", Json::Str("hello_ack".into())),
                ("version", Json::Num(*version as f64)),
                ("max_atoms", Json::Num(*max_atoms as f64)),
                ("buckets", Json::arr_f64(&b)),
            ])
        }
        ServerMsg::Frame { seq, frame } => Json::obj(vec![
            ("type", Json::Str("frame".into())),
            ("seq", Json::Num(*seq as f64)),
            ("frame", frame_to_json(frame)),
        ]),
        ServerMsg::Done { seq, result } => {
            let mut pairs = vec![
                ("type", Json::Str("done".into())),
                ("seq", Json::Num(*seq as f64)),
            ];
            match result {
                Ok(r) => pairs.push(("ok", reply_to_json(r))),
                Err(e) => pairs.push(("err", error_to_json(e))),
            }
            Json::obj(pairs)
        }
        ServerMsg::Pong { health, queue_depth } => Json::obj(vec![
            ("type", Json::Str("pong".into())),
            ("health", Json::Str(health_to_str(*health).to_string())),
            ("queue_depth", Json::Num(*queue_depth as f64)),
        ]),
        ServerMsg::StatsAck { metrics } => Json::obj(vec![
            ("type", Json::Str("stats_ack".into())),
            ("metrics", metrics.to_json()),
        ]),
    };
    j.to_string()
}

pub fn decode_server(s: &str) -> Result<ServerMsg, WireError> {
    decode_server_json(s).map_err(WireError::Codec)
}

fn decode_server_json(s: &str) -> Result<ServerMsg, String> {
    let v = json::parse_limited(s, &Limits::default())
        .map_err(|e| e.to_string())?;
    match need_str(&v, "type")? {
        "hello_ack" => {
            let buckets = f64_list(&v, "buckets")?
                .into_iter()
                .map(|b| {
                    if b.is_finite() && b >= 0.0 && b == b.trunc() {
                        Ok(b as usize)
                    } else {
                        Err(format!("bad bucket width {b}"))
                    }
                })
                .collect::<Result<Vec<usize>, String>>()?;
            Ok(ServerMsg::HelloAck {
                version: need_u64(&v, "version")?,
                max_atoms: need_usize(&v, "max_atoms")?,
                buckets,
            })
        }
        "frame" => Ok(ServerMsg::Frame {
            seq: need_u64(&v, "seq")?,
            frame: frame_from_json(need(&v, "frame")?)?,
        }),
        "done" => {
            let seq = need_u64(&v, "seq")?;
            let result = if let Some(ok) = v.get("ok") {
                Ok(reply_from_json(ok)?)
            } else if let Some(err) = v.get("err") {
                Err(error_from_json(err)?)
            } else {
                return Err("done without 'ok' or 'err'".to_string());
            };
            Ok(ServerMsg::Done { seq, result })
        }
        "pong" => Ok(ServerMsg::Pong {
            health: health_from_str(need_str(&v, "health")?)?,
            queue_depth: need_usize(&v, "queue_depth")?,
        }),
        "stats_ack" => Ok(ServerMsg::StatsAck {
            metrics: MetricsSnapshot::from_json(need(&v, "metrics")?)?,
        }),
        other => Err(format!("unknown server message type '{other}'")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn structure(n: usize) -> Structure {
        Structure {
            pos: (0..n).map(|i| [i as f64 * 1.5, 0.25, -2.0]).collect(),
            species: (0..n).map(|i| i % 3).collect(),
        }
    }

    fn roundtrip_client(m: ClientMsg) -> ClientMsg {
        decode_client(&encode_client(&m)).expect("client roundtrip")
    }

    fn roundtrip_server(m: ServerMsg) -> ServerMsg {
        decode_server(&encode_server(&m)).expect("server roundtrip")
    }

    #[test]
    fn every_task_kind_roundtrips() {
        let tasks = vec![
            Task::EnergyOnly { structure: structure(2) },
            Task::EnergyForces { structure: structure(3) },
            Task::Relax { structure: structure(2), max_steps: 50 },
            Task::MdRollout { structure: structure(2), steps: 9, dt: 0.002 },
            Task::Batch { structures: vec![structure(1), structure(4)] },
        ];
        for task in tasks {
            let m = roundtrip_client(ClientMsg::Submit {
                seq: 7,
                deadline_ms: Some(250),
                model: Some("prod".to_string()),
                task: task.clone(),
            });
            match m {
                ClientMsg::Submit { seq, deadline_ms, model, task: got } => {
                    assert_eq!(seq, 7);
                    assert_eq!(deadline_ms, Some(250));
                    assert_eq!(model.as_deref(), Some("prod"));
                    assert_eq!(got.label(), task.label());
                    assert_eq!(got.n_atoms_max(), task.n_atoms_max());
                    let (a, b) = (got.structures(), task.structures());
                    assert_eq!(a.len(), b.len());
                    for (x, y) in a.iter().zip(b.iter()) {
                        assert_eq!(x.pos, y.pos);
                        assert_eq!(x.species, y.species);
                    }
                }
                other => panic!("expected Submit, got {other:?}"),
            }
        }
    }

    #[test]
    fn submit_without_options_roundtrips() {
        match roundtrip_client(ClientMsg::Submit {
            seq: 1,
            deadline_ms: None,
            model: None,
            task: Task::EnergyOnly { structure: structure(1) },
        }) {
            ClientMsg::Submit { deadline_ms: None, model: None, .. } => {}
            other => panic!("options must stay None: {other:?}"),
        }
    }

    #[test]
    fn control_messages_roundtrip() {
        assert!(matches!(
            roundtrip_client(ClientMsg::Hello {
                version: 1,
                name: "lt-3".to_string()
            }),
            ClientMsg::Hello { version: 1, .. }
        ));
        assert!(matches!(
            roundtrip_client(ClientMsg::Cancel { seq: 12 }),
            ClientMsg::Cancel { seq: 12 }
        ));
        assert!(matches!(roundtrip_client(ClientMsg::Ping), ClientMsg::Ping));
        assert!(matches!(roundtrip_client(ClientMsg::Drain), ClientMsg::Drain));
        assert!(matches!(roundtrip_client(ClientMsg::Stats), ClientMsg::Stats));
        assert!(matches!(roundtrip_client(ClientMsg::Bye), ClientMsg::Bye));
    }

    #[test]
    fn every_reply_kind_roundtrips() {
        let replies = vec![
            Reply::Energy(EnergyOut { id: 3, energy: -7.25, latency_s: 0.01 }),
            Reply::EnergyForces(ForceResponse {
                id: 4,
                energy: -1.5,
                forces: vec![[0.1, -0.5, 2.0]; 3],
                latency_s: 0.02,
            }),
            Reply::Relaxed(RelaxResult {
                pos: vec![[0.0, 1.0, 2.0]; 2],
                energy: -3.0,
                max_force: 0.001,
                steps: 17,
                converged: true,
                energy_trace: vec![-1.0, -2.0, -3.0],
            }),
            Reply::Rollout(RolloutSummary {
                id: 5,
                steps: 100,
                final_pos: vec![[1.0, 1.0, 1.0]],
                final_energy: -0.5,
            }),
            Reply::Batch(vec![ForceResponse {
                id: 6,
                energy: 0.25,
                forces: vec![[0.0, 0.0, 0.0]],
                latency_s: 0.005,
            }]),
        ];
        for reply in replies {
            match roundtrip_server(ServerMsg::Done {
                seq: 9,
                result: Ok(reply.clone()),
            }) {
                ServerMsg::Done { seq: 9, result: Ok(got) } => {
                    assert_eq!(format!("{got:?}"), format!("{reply:?}"));
                }
                other => panic!("expected Done(Ok), got {other:?}"),
            }
        }
    }

    #[test]
    fn every_error_code_roundtrips() {
        let errors = vec![
            ServiceError::Rejected("too big".to_string()),
            ServiceError::Overloaded {
                retry_after: Duration::from_millis(75),
            },
            ServiceError::DeadlineExceeded,
            ServiceError::Canceled,
            ServiceError::Shutdown,
            ServiceError::Dropped("worker died".to_string()),
            ServiceError::Exec(ExecFault::Backend("no model".to_string())),
            ServiceError::Exec(ExecFault::NonFinite("nan".to_string())),
            ServiceError::Exec(ExecFault::BudgetExhausted("5".to_string())),
            ServiceError::Protocol("shape".to_string()),
        ];
        for e in errors {
            match roundtrip_server(ServerMsg::Done {
                seq: 2,
                result: Err(e.clone()),
            }) {
                ServerMsg::Done { result: Err(got), .. } => {
                    assert_eq!(got, e)
                }
                other => panic!("expected Done(Err), got {other:?}"),
            }
        }
    }

    #[test]
    fn streamed_frames_and_probes_roundtrip() {
        match roundtrip_server(ServerMsg::Frame {
            seq: 4,
            frame: Frame {
                step: 2,
                time: 0.006,
                energy: -1.25,
                kinetic: 0.75,
                pos: vec![[1.0, 2.0, 3.0]],
            },
        }) {
            ServerMsg::Frame { seq: 4, frame } => {
                assert_eq!(frame.step, 2);
                assert_eq!(frame.pos, vec![[1.0, 2.0, 3.0]]);
            }
            other => panic!("expected Frame, got {other:?}"),
        }
        for h in
            [HealthState::Healthy, HealthState::Shedding, HealthState::Draining]
        {
            match roundtrip_server(ServerMsg::Pong {
                health: h,
                queue_depth: 11,
            }) {
                ServerMsg::Pong { health, queue_depth: 11 } => {
                    assert_eq!(health, h)
                }
                other => panic!("expected Pong, got {other:?}"),
            }
        }
        match roundtrip_server(ServerMsg::HelloAck {
            version: 1,
            max_atoms: 256,
            buckets: vec![32, 64, 256],
        }) {
            ServerMsg::HelloAck { version: 1, max_atoms: 256, buckets } => {
                assert_eq!(buckets, vec![32, 64, 256])
            }
            other => panic!("expected HelloAck, got {other:?}"),
        }
        let mut snap = MetricsSnapshot::default();
        snap.requests = 10;
        snap.responses = 10;
        snap.p99_ns = 1.5e6;
        match roundtrip_server(ServerMsg::StatsAck { metrics: snap }) {
            ServerMsg::StatsAck { metrics } => assert_eq!(metrics, snap),
            other => panic!("expected StatsAck, got {other:?}"),
        }
    }

    #[test]
    fn malformed_payloads_are_typed_codec_errors() {
        for bad in [
            "",
            "not json",
            "{}",
            "{\"type\":\"nope\"}",
            "{\"type\":\"submit\",\"seq\":1}",
            "{\"type\":\"submit\",\"seq\":-4,\"task\":{}}",
            "{\"type\":\"submit\",\"seq\":1,\"task\":{\"kind\":\"energy\",\
             \"structure\":{\"pos\":[1,2],\"species\":[0]}}}",
        ] {
            assert!(
                matches!(decode_client(bad), Err(WireError::Codec(_))),
                "input {bad:?} must be a codec error"
            );
        }
        for bad in ["", "[]", "{\"type\":\"done\",\"seq\":1}"] {
            assert!(matches!(decode_server(bad), Err(WireError::Codec(_))));
        }
    }
}
