//! The true multi-process loadtest: N client *processes* hammering M
//! replica *processes* behind one front door, over real sockets.
//!
//! The orchestrator ([`run_cluster_loadtest`]) spawns everything from
//! one binary (`gaunt-tp replica` / `gaunt-tp frontdoor` /
//! `gaunt-tp net-worker`), so the integration test and `make loadtest`
//! exercise genuinely separate address spaces — a replica being
//! SIGKILLed mid-load is a real process death, not a simulated one.
//!
//! Ledger discipline: every client worker accounts for every request it
//! issued (`n = ok + rejected + canceled + expired + failed`), workers
//! print their ledger as one `NETLOAD {json}` line on stdout, and the
//! orchestrator aggregates and re-checks the reconciliation.

use std::io::Read;
use std::path::Path;
use std::process::{Child, Command, Stdio};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::{
    EnergyForces, MetricsSnapshot, Request, ServiceError, Structure,
};
use crate::util::json::{self, Json};
use crate::util::rng::Rng;

use super::client::NetClient;
use super::{temp_socket_path, Addr};

/// One process's (or the aggregate's) request ledger.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ClientLedger {
    pub n: u64,
    pub ok: u64,
    /// typed `Rejected` + `Overloaded` (wire-visible backpressure)
    pub rejected: u64,
    pub canceled: u64,
    pub expired: u64,
    pub failed: u64,
    pub p50_ms: f64,
    pub p99_ms: f64,
}

impl ClientLedger {
    /// Every issued request landed in exactly one outcome bucket.
    pub fn reconciles(&self) -> bool {
        self.n
            == self.ok
                + self.rejected
                + self.canceled
                + self.expired
                + self.failed
    }

    pub fn merge(&mut self, other: &ClientLedger) {
        self.n += other.n;
        self.ok += other.ok;
        self.rejected += other.rejected;
        self.canceled += other.canceled;
        self.expired += other.expired;
        self.failed += other.failed;
        self.p50_ms = self.p50_ms.max(other.p50_ms);
        self.p99_ms = self.p99_ms.max(other.p99_ms);
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n", Json::Num(self.n as f64)),
            ("ok", Json::Num(self.ok as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("canceled", Json::Num(self.canceled as f64)),
            ("expired", Json::Num(self.expired as f64)),
            ("failed", Json::Num(self.failed as f64)),
            ("p50_ms", Json::Num(self.p50_ms)),
            ("p99_ms", Json::Num(self.p99_ms)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<ClientLedger, String> {
        let f = |key: &str| -> Result<f64, String> {
            v.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("ledger missing '{key}'"))
        };
        Ok(ClientLedger {
            n: f("n")? as u64,
            ok: f("ok")? as u64,
            rejected: f("rejected")? as u64,
            canceled: f("canceled")? as u64,
            expired: f("expired")? as u64,
            failed: f("failed")? as u64,
            p50_ms: f("p50_ms")?,
            p99_ms: f("p99_ms")?,
        })
    }
}

/// What one loadtest run produced.
#[derive(Debug)]
pub struct LoadReport {
    pub per_client: Vec<ClientLedger>,
    pub total: ClientLedger,
    /// the front door's merged fleet ledger, if reachable at the end
    pub frontdoor_stats: Option<MetricsSnapshot>,
    pub killed_replica: bool,
    pub wall: Duration,
}

impl LoadReport {
    pub fn success_rate(&self) -> f64 {
        if self.total.n == 0 {
            return 0.0;
        }
        self.total.ok as f64 / self.total.n as f64
    }
}

/// Orchestrator knobs.
#[derive(Clone, Debug)]
pub struct LoadOpts {
    pub replicas: usize,
    pub clients: usize,
    pub requests_per_client: usize,
    /// per-request deadline budget
    pub deadline_ms: u64,
    /// SIGKILL one replica process mid-load (resilience demo)
    pub kill_one: bool,
    /// worker threads per replica process
    pub workers: usize,
    /// concurrent submission threads per client process — raise above
    /// replica capacity to demonstrate 2x overload shedding
    pub concurrency: usize,
    pub seed: u64,
}

impl Default for LoadOpts {
    fn default() -> Self {
        LoadOpts {
            replicas: 2,
            clients: 2,
            requests_per_client: 40,
            deadline_ms: 10_000,
            kill_one: false,
            workers: 2,
            concurrency: 4,
            seed: 20260807,
        }
    }
}

/// A jittered-grid cluster, matching the serving benches' workload.
pub fn cluster(n: usize, seed: u64) -> Structure {
    let mut rng = Rng::new(seed);
    let side = (n as f64).cbrt().ceil() as usize;
    let spacing = 3.5;
    let mut pos = Vec::with_capacity(n);
    let mut species = Vec::with_capacity(n);
    'fill: for i in 0..side {
        for j in 0..side {
            for k in 0..side {
                if pos.len() == n {
                    break 'fill;
                }
                pos.push([
                    i as f64 * spacing + rng.uniform(-0.3, 0.3),
                    j as f64 * spacing + rng.uniform(-0.3, 0.3),
                    k as f64 * spacing + rng.uniform(-0.3, 0.3),
                ]);
                species.push(pos.len() % 3);
            }
        }
    }
    Structure::new(pos, species)
}

fn percentile(sorted_ms: &[f64], q: f64) -> f64 {
    if sorted_ms.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_ms.len() - 1) as f64 * q).round() as usize;
    sorted_ms[idx]
}

/// The body of one client process (also runnable in-process for unit
/// tests): `concurrency` threads submit `n_requests` energy+forces
/// tasks total and account for every outcome.
pub fn run_client_worker(
    addr: &Addr, n_requests: usize, concurrency: usize, deadline_ms: u64,
    seed: u64,
) -> Result<ClientLedger, String> {
    let client = Arc::new(connect_with_retry(addr, Duration::from_secs(10))?);
    let issued = Arc::new(std::sync::atomic::AtomicUsize::new(0));
    let ledger = Arc::new(Mutex::new(ClientLedger::default()));
    let latencies: Arc<Mutex<Vec<f64>>> = Arc::new(Mutex::new(Vec::new()));
    let mut handles = Vec::new();
    for t in 0..concurrency.max(1) {
        let client = client.clone();
        let issued = issued.clone();
        let ledger = ledger.clone();
        let latencies = latencies.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(seed ^ (t as u64).wrapping_mul(0x9e3779b9));
            loop {
                let i = issued
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n_requests {
                    // un-claim the overshoot so `n` stays exact
                    issued
                        .fetch_sub(1, std::sync::atomic::Ordering::Relaxed);
                    return;
                }
                let n_atoms = 8 + rng.below(25);
                let st = cluster(n_atoms, seed.wrapping_add(i as u64));
                let started = Instant::now();
                let req = Request::new(EnergyForces(st))
                    .deadline(Duration::from_millis(deadline_ms));
                let outcome = match client.submit(req) {
                    Ok(ticket) => ticket.wait().map(|_| ()),
                    Err(e) => Err(e),
                };
                let ms = started.elapsed().as_secs_f64() * 1e3;
                let mut l = ledger.lock().unwrap_or_else(|e| e.into_inner());
                l.n += 1;
                match outcome {
                    Ok(()) => {
                        l.ok += 1;
                        latencies
                            .lock()
                            .unwrap_or_else(|e| e.into_inner())
                            .push(ms);
                    }
                    Err(
                        ServiceError::Rejected(_)
                        | ServiceError::Overloaded { .. },
                    ) => l.rejected += 1,
                    Err(ServiceError::Canceled) => l.canceled += 1,
                    Err(ServiceError::DeadlineExceeded) => l.expired += 1,
                    Err(_) => l.failed += 1,
                }
            }
        }));
    }
    for h in handles {
        let _ = h.join();
    }
    let mut out =
        ledger.lock().unwrap_or_else(|e| e.into_inner()).clone();
    let mut lat = latencies.lock().unwrap_or_else(|e| e.into_inner()).clone();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    out.p50_ms = percentile(&lat, 0.50);
    out.p99_ms = percentile(&lat, 0.99);
    client.close();
    Ok(out)
}

/// Connect, retrying while the serving processes come up.
pub fn connect_with_retry(
    addr: &Addr, budget: Duration,
) -> Result<NetClient, String> {
    let deadline = Instant::now() + budget;
    loop {
        match NetClient::connect(addr) {
            Ok(c) => return Ok(c),
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(format!("{addr} never came up: {e}"));
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

struct ChildGuard {
    child: Child,
    #[allow(dead_code)]
    tag: String,
}

impl Drop for ChildGuard {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// Spawn M replica processes + 1 front-door process + N client
/// processes from `exe` (the `gaunt-tp` binary), run the load, and
/// aggregate the ledgers.  Every child is killed on exit, success or
/// not.
pub fn run_cluster_loadtest(
    exe: &Path, opts: &LoadOpts,
) -> Result<LoadReport, String> {
    let started = Instant::now();
    let run_tag = std::process::id();

    // ---- replicas ----
    let mut replica_addrs: Vec<Addr> = Vec::new();
    let mut replicas: Vec<ChildGuard> = Vec::new();
    for i in 0..opts.replicas {
        let sock = temp_socket_path(&format!("lt{run_tag}-r{i}"));
        let addr = Addr::Unix(sock.clone());
        let child = Command::new(exe)
            .args([
                "replica",
                "--listen",
                &addr.to_string(),
                "--workers",
                &opts.workers.to_string(),
                "--name",
                &format!("r{i}"),
            ])
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .map_err(|e| format!("spawn replica {i}: {e}"))?;
        replicas.push(ChildGuard { child, tag: format!("replica-{i}") });
        replica_addrs.push(addr);
    }

    // ---- front door ----
    let fd_sock = temp_socket_path(&format!("lt{run_tag}-fd"));
    let fd_addr = Addr::Unix(fd_sock.clone());
    let mut fd_args: Vec<String> = vec![
        "frontdoor".to_string(),
        "--listen".to_string(),
        fd_addr.to_string(),
    ];
    for a in &replica_addrs {
        fd_args.push("--replica".to_string());
        fd_args.push(a.to_string());
    }
    let fd_child = Command::new(exe)
        .args(&fd_args)
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .map_err(|e| format!("spawn frontdoor: {e}"))?;
    let _fd_guard = ChildGuard { child: fd_child, tag: "frontdoor".into() };

    // ---- readiness: the front door answers a ping and at least one
    // replica is routable (probe one cheap submission) ----
    {
        let probe = connect_with_retry(&fd_addr, Duration::from_secs(15))?;
        let ready_by = Instant::now() + Duration::from_secs(15);
        loop {
            let req = Request::new(EnergyForces(cluster(4, 1)))
                .deadline(Duration::from_millis(2000));
            match probe.submit(req).and_then(|t| t.wait()) {
                Ok(_) => break,
                Err(_) if Instant::now() < ready_by => {
                    std::thread::sleep(Duration::from_millis(100));
                }
                Err(e) => {
                    return Err(format!("cluster never became ready: {e}"))
                }
            }
        }
        probe.close();
    }

    // ---- client processes ----
    let mut clients: Vec<Child> = Vec::new();
    for c in 0..opts.clients {
        let child = Command::new(exe)
            .args([
                "net-worker",
                "--connect",
                &fd_addr.to_string(),
                "--requests",
                &opts.requests_per_client.to_string(),
                "--concurrency",
                &opts.concurrency.to_string(),
                "--deadline-ms",
                &opts.deadline_ms.to_string(),
                "--seed",
                &(opts.seed.wrapping_add(c as u64 * 7919)).to_string(),
            ])
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .map_err(|e| format!("spawn client {c}: {e}"))?;
        clients.push(child);
    }

    // ---- optional mid-load replica kill ----
    let mut killed = false;
    if opts.kill_one && !replicas.is_empty() {
        std::thread::sleep(Duration::from_millis(300));
        let victim = &mut replicas[0];
        let _ = victim.child.kill();
        let _ = victim.child.wait();
        killed = true;
    }

    // ---- harvest client ledgers ----
    let mut per_client = Vec::new();
    for (c, mut child) in clients.into_iter().enumerate() {
        let mut out = String::new();
        if let Some(stdout) = child.stdout.as_mut() {
            let _ = stdout.read_to_string(&mut out);
        }
        let status =
            child.wait().map_err(|e| format!("wait client {c}: {e}"))?;
        let line = out
            .lines()
            .find_map(|l| l.trim().strip_prefix("NETLOAD "))
            .ok_or_else(|| {
                format!(
                    "client {c} (exit {status}) printed no NETLOAD ledger; \
                     stdout: {out:?}"
                )
            })?;
        let v = json::parse(line)
            .map_err(|e| format!("client {c} ledger: {e}"))?;
        let ledger = ClientLedger::from_json(&v)
            .map_err(|e| format!("client {c} ledger: {e}"))?;
        if !ledger.reconciles() {
            return Err(format!(
                "client {c} ledger does not reconcile: {ledger:?}"
            ));
        }
        per_client.push(ledger);
    }
    let mut total = ClientLedger::default();
    for l in &per_client {
        total.merge(l);
    }

    // ---- fleet stats from the front door ----
    let frontdoor_stats = NetClient::connect(&fd_addr)
        .ok()
        .and_then(|c| {
            let s = c.stats(Duration::from_secs(5)).ok();
            c.close();
            s
        });

    // children die via ChildGuard drops; unix socket files with them
    let report = LoadReport {
        per_client,
        total,
        frontdoor_stats,
        killed_replica: killed,
        wall: started.elapsed(),
    };
    let _ = std::fs::remove_file(&fd_sock);
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_roundtrips_and_reconciles() {
        let l = ClientLedger {
            n: 10,
            ok: 6,
            rejected: 2,
            canceled: 1,
            expired: 1,
            failed: 0,
            p50_ms: 1.5,
            p99_ms: 9.0,
        };
        assert!(l.reconciles());
        let parsed = json::parse(&l.to_json().to_string()).unwrap();
        let back = ClientLedger::from_json(&parsed).unwrap();
        assert_eq!(back, l);
        let mut bad = l.clone();
        bad.ok += 1;
        assert!(!bad.reconciles());
    }

    #[test]
    fn ledgers_merge_additively() {
        let mut a = ClientLedger {
            n: 5,
            ok: 5,
            p50_ms: 1.0,
            p99_ms: 2.0,
            ..Default::default()
        };
        let b = ClientLedger {
            n: 3,
            ok: 2,
            failed: 1,
            p50_ms: 4.0,
            p99_ms: 1.0,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.n, 8);
        assert_eq!(a.ok, 7);
        assert_eq!(a.failed, 1);
        assert_eq!(a.p50_ms, 4.0);
        assert_eq!(a.p99_ms, 2.0);
        assert!(a.reconciles());
    }

    #[test]
    fn cluster_generator_is_deterministic() {
        let a = cluster(17, 42);
        let b = cluster(17, 42);
        assert_eq!(a.pos, b.pos);
        assert_eq!(a.species, b.species);
        assert_eq!(a.n_atoms(), 17);
    }

    #[test]
    fn percentile_picks_sane_values() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.5), 50.0);
        assert_eq!(percentile(&xs, 0.99), 99.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }
}
