//! The wire layer: multi-process serving over TCP and Unix-domain
//! sockets (DESIGN.md §14).
//!
//! ```text
//!   NetClient ──[frame::write_frame]──▶ FrontDoor ──▶ Replica ──▶ Service
//!       ▲                                  │shard by shape bucket,
//!       │ Ticket-shaped API                │least-outstanding, health-
//!       │ (wait/try_poll/next_frame/       │probed, reroutes around
//!       │  cancel; reply-on-drop)          │dead replicas
//! ```
//!
//! * [`frame`] — length-prefixed, versioned frames with typed
//!   [`frame::WireError`]s (torn reads are `Truncated`, never a hang).
//! * [`proto`] — `Task`/`Reply`/`ServiceError` <-> JSON via the
//!   hardened `util::json` codec (zero new dependencies).
//! * [`replica`] — a blocking socket server wrapping one
//!   `coordinator::Service`; wire `cancel`/disconnect releases the
//!   service-side ticket.
//! * [`client`] — [`client::NetClient`], source-compatible with the
//!   in-process `Client`: `submit(Request<T>)` returns a typed
//!   [`client::NetTicket`].
//! * [`frontdoor`] — multi-replica router: shape-bucket sharding,
//!   health probes, wire-visible backpressure, reroute on replica
//!   death, graceful drain.
//! * [`loadtest`] — the true multi-process load generator (N client
//!   processes x M replica processes) behind `make loadtest`.
//!
//! Everything is blocking std sockets + threads, matching the repo's
//! no-tokio constraint; liveness comes from the same reply-on-drop
//! discipline the in-process protocol uses.

pub mod client;
pub mod frame;
pub mod frontdoor;
pub mod loadtest;
pub mod proto;
pub mod replica;

pub use client::{NetClient, NetTicket};
pub use frame::{read_frame, write_frame, WireError};
pub use frontdoor::{FrontDoor, FrontDoorConfig, RespawnPolicy};
pub use replica::Replica;

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::util::failpoint;

// ---------------------------------------------------------------------
// addresses
// ---------------------------------------------------------------------

/// A serving address: `host:port` for TCP, `unix:/path` for a
/// Unix-domain socket.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Addr {
    Tcp(String),
    Unix(PathBuf),
}

impl Addr {
    /// Parse `"unix:/path/to.sock"` or `"host:port"`.
    pub fn parse(s: &str) -> Result<Addr, String> {
        if let Some(p) = s.strip_prefix("unix:") {
            if p.is_empty() {
                return Err("empty unix socket path".to_string());
            }
            return Ok(Addr::Unix(PathBuf::from(p)));
        }
        if s.rsplit_once(':').map_or(false, |(h, p)| {
            !h.is_empty() && p.parse::<u16>().is_ok()
        }) {
            return Ok(Addr::Tcp(s.to_string()));
        }
        Err(format!(
            "bad address '{s}': expected host:port or unix:/path"
        ))
    }
}

impl std::fmt::Display for Addr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Addr::Tcp(a) => write!(f, "{a}"),
            Addr::Unix(p) => write!(f, "unix:{}", p.display()),
        }
    }
}

/// A fresh per-process, per-call Unix socket path under the system temp
/// dir — what the tests and the multi-process loadtest bind on.
pub fn temp_socket_path(tag: &str) -> PathBuf {
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    let n = COUNTER.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!(
        "gtp-{tag}-{}-{n}.sock",
        std::process::id()
    ))
}

// ---------------------------------------------------------------------
// connections + listeners
// ---------------------------------------------------------------------

/// One bidirectional byte stream, TCP or Unix-domain, unified behind
/// `Read`/`Write` so the frame layer never cares which.
#[derive(Debug)]
pub enum Conn {
    Tcp(TcpStream),
    Unix(UnixStream),
}

impl Conn {
    pub fn connect(addr: &Addr) -> io::Result<Conn> {
        match addr {
            Addr::Tcp(a) => {
                let s = TcpStream::connect(a)?;
                // latency matters more than throughput for small frames
                let _ = s.set_nodelay(true);
                Ok(Conn::Tcp(s))
            }
            Addr::Unix(p) => UnixStream::connect(p).map(Conn::Unix),
        }
    }

    /// An independently readable/writable handle onto the same socket
    /// (reader thread + writer mutex pattern).
    pub fn try_clone(&self) -> io::Result<Conn> {
        match self {
            Conn::Tcp(s) => s.try_clone().map(Conn::Tcp),
            Conn::Unix(s) => s.try_clone().map(Conn::Unix),
        }
    }

    /// Close both directions; blocked reads on any clone return EOF.
    pub fn shutdown_both(&self) {
        match self {
            Conn::Tcp(s) => {
                let _ = s.shutdown(Shutdown::Both);
            }
            Conn::Unix(s) => {
                let _ = s.shutdown(Shutdown::Both);
            }
        }
    }

    pub fn set_read_timeout(&self, d: Option<Duration>) -> io::Result<()> {
        match self {
            Conn::Tcp(s) => s.set_read_timeout(d),
            Conn::Unix(s) => s.set_read_timeout(d),
        }
    }
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.read(buf),
            Conn::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.write(buf),
            Conn::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            Conn::Tcp(s) => s.flush(),
            Conn::Unix(s) => s.flush(),
        }
    }
}

/// Live-connection registry for a server's shutdown path: each handler
/// registers a severable clone of its socket on entry and deregisters
/// it on exit, so `sever_all` reaches every open connection without the
/// registry leaking one fd per connection ever served.
pub(crate) struct ConnRegistry {
    next_id: AtomicU64,
    conns: Mutex<HashMap<u64, Conn>>,
}

impl ConnRegistry {
    pub(crate) fn new() -> ConnRegistry {
        ConnRegistry {
            next_id: AtomicU64::new(0),
            conns: Mutex::new(HashMap::new()),
        }
    }

    /// Register a severable handle onto `conn`; `None` if the socket
    /// could not be cloned (the caller serves unregistered).
    pub(crate) fn register(&self, conn: &Conn) -> Option<u64> {
        let clone = conn.try_clone().ok()?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.conns
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .insert(id, clone);
        Some(id)
    }

    /// Drop the registered clone once the handler is done with the
    /// connection.
    pub(crate) fn deregister(&self, id: Option<u64>) {
        if let Some(id) = id {
            self.conns
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .remove(&id);
        }
    }

    /// Shut down every still-registered connection (server shutdown).
    pub(crate) fn sever_all(&self) {
        for (_, conn) in
            self.conns.lock().unwrap_or_else(|e| e.into_inner()).drain()
        {
            conn.shutdown_both();
        }
    }
}

/// A bound accept socket (TCP or Unix).
pub enum Listener {
    Tcp(TcpListener),
    Unix(UnixListener),
}

impl Listener {
    /// Bind, returning the listener plus the ACTUAL address (a TCP bind
    /// on port 0 resolves to the kernel-assigned port).  A stale Unix
    /// socket file at the path is unlinked first.
    pub fn bind(addr: &Addr) -> io::Result<(Listener, Addr)> {
        match addr {
            Addr::Tcp(a) => {
                let l = TcpListener::bind(a)?;
                let actual = Addr::Tcp(l.local_addr()?.to_string());
                Ok((Listener::Tcp(l), actual))
            }
            Addr::Unix(p) => {
                let _ = std::fs::remove_file(p);
                let l = UnixListener::bind(p)?;
                Ok((Listener::Unix(l), Addr::Unix(p.clone())))
            }
        }
    }

    pub fn accept(&self) -> io::Result<Conn> {
        match self {
            Listener::Tcp(l) => l.accept().map(|(s, _)| {
                let _ = s.set_nodelay(true);
                Conn::Tcp(s)
            }),
            Listener::Unix(l) => l.accept().map(|(s, _)| Conn::Unix(s)),
        }
    }
}

/// One blocking accept loop on its own thread, spawning a detached
/// handler thread per connection.  Failpoint `net.accept` (chaos
/// suite): an `error` policy refuses the connection (dropped on the
/// floor — clients see EOF and surface a typed error), `delay` stalls
/// the accept path, `panic` kills the acceptor.
pub(crate) fn spawn_acceptor(
    listener: Listener, stop: Arc<AtomicBool>, tag: String,
    handler: Arc<dyn Fn(Conn) + Send + Sync + 'static>,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("{tag}-accept"))
        .spawn(move || {
            let mut conn_idx = 0usize;
            loop {
                let conn = match listener.accept() {
                    Ok(c) => c,
                    Err(_) => {
                        if stop.load(Ordering::Relaxed) {
                            return;
                        }
                        // transient accept error (EMFILE, EINTR): don't
                        // spin the core while the condition persists
                        std::thread::sleep(Duration::from_millis(10));
                        continue;
                    }
                };
                if stop.load(Ordering::Relaxed) {
                    // the shutdown poke connection itself lands here
                    conn.shutdown_both();
                    return;
                }
                match failpoint::check("net.accept") {
                    Some(failpoint::Fault::Error(_)) => {
                        conn.shutdown_both();
                        continue;
                    }
                    Some(failpoint::Fault::Nan) | None => {}
                }
                conn_idx += 1;
                let h = handler.clone();
                let _ = std::thread::Builder::new()
                    .name(format!("{tag}-conn-{conn_idx}"))
                    .spawn(move || h(conn));
            }
        })
        .expect("spawn acceptor thread")
}

/// Unblock a blocking `accept` after its stop flag was set, by making
/// one throwaway connection.
pub(crate) fn poke(addr: &Addr) {
    if let Ok(c) = Conn::connect(addr) {
        c.shutdown_both();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn addr_parsing() {
        assert_eq!(
            Addr::parse("unix:/tmp/x.sock").unwrap(),
            Addr::Unix(PathBuf::from("/tmp/x.sock"))
        );
        assert_eq!(
            Addr::parse("127.0.0.1:8080").unwrap(),
            Addr::Tcp("127.0.0.1:8080".to_string())
        );
        assert_eq!(
            Addr::parse("localhost:0").unwrap(),
            Addr::Tcp("localhost:0".to_string())
        );
        assert!(Addr::parse("unix:").is_err());
        assert!(Addr::parse("nonsense").is_err());
        assert!(Addr::parse("host:notaport").is_err());
        // display round-trips
        for s in ["unix:/tmp/y.sock", "127.0.0.1:9999"] {
            assert_eq!(Addr::parse(s).unwrap().to_string(), s);
        }
    }

    #[test]
    fn temp_socket_paths_are_unique() {
        assert_ne!(temp_socket_path("t"), temp_socket_path("t"));
    }
}
