//! Named-model registry with versioned endpoints and hot swap.
//!
//! Each endpoint is a name (`"default"` unless the request says
//! otherwise) holding the *current* [`ModelVersion`] behind an
//! `RwLock<Arc<_>>` — the ArcSwap pattern expressible without external
//! crates: readers take the read lock only long enough to clone the
//! `Arc` (no allocation, no contention with other readers), writers
//! swap the `Arc` in one short write section.  [`Registry::register`]
//! on an existing name IS the hot swap: checkpoints promoted from
//! [`crate::coordinator::trainer::NativeTrainer`] become live without
//! stopping the server.
//!
//! **Torn-batch freedom.**  Workers resolve an endpoint ONCE per padded
//! batch (and once per relax/rollout) and keep the `Arc<ModelVersion>`
//! for the whole execution; a swap mid-batch therefore changes which
//! model the NEXT batch sees, never the rows of an in-flight one.
//! In-flight versions are freed by the last `Arc` owner, so swaps are
//! also safe against use-after-free by construction.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::err;
use crate::model::Model;
use crate::util::error::Result;
use crate::util::{failpoint, sync};

/// The endpoint every request without an explicit `model` name hits.
pub const DEFAULT_ENDPOINT: &str = "default";

/// One immutable (name, version, model) triple.  Workers hold this for
/// the duration of a batch.
pub struct ModelVersion {
    pub name: String,
    /// globally monotone: every `register` (first or swap) bumps it
    pub version: u64,
    pub model: Arc<Model>,
}

struct Endpoint {
    current: RwLock<Arc<ModelVersion>>,
}

/// Named, versioned model endpoints with lock-free-read hot swap.
pub struct Registry {
    endpoints: RwLock<HashMap<String, Arc<Endpoint>>>,
    version_counter: AtomicU64,
}

impl Default for Registry {
    fn default() -> Self {
        Registry::new()
    }
}

impl Registry {
    pub fn new() -> Registry {
        Registry {
            endpoints: RwLock::new(HashMap::new()),
            version_counter: AtomicU64::new(0),
        }
    }

    /// Create or hot-swap the endpoint `name`; returns the new version.
    /// Existing readers keep the version they already resolved.
    ///
    /// A model with any non-finite parameter is refused: promoting a
    /// NaN checkpoint would turn every subsequent inference into a
    /// non-finite reply, so the poison is stopped at the swap point and
    /// the previous version keeps serving untouched.
    pub fn register(&self, name: &str, model: Arc<Model>) -> Result<u64> {
        if let Some(i) = model.params.iter().position(|p| !p.is_finite()) {
            return Err(err!(
                "refusing to register model at endpoint '{name}': \
                 parameter {i} of {} is non-finite ({})",
                model.params.len(),
                model.params[i]
            ));
        }
        let version =
            self.version_counter.fetch_add(1, Ordering::Relaxed) + 1;
        let mv = Arc::new(ModelVersion {
            name: name.to_string(),
            version,
            model,
        });
        // fast path: endpoint exists — swap under the endpoint's own
        // write lock without touching the map
        {
            let map = sync::read(&self.endpoints);
            if let Some(ep) = map.get(name) {
                *sync::write(&ep.current) = mv;
                return Ok(version);
            }
        }
        // slow path: insert (double-checked against racing registers)
        let mut map = sync::write(&self.endpoints);
        match map.get(name) {
            Some(ep) => *sync::write(&ep.current) = mv,
            None => {
                map.insert(
                    name.to_string(),
                    Arc::new(Endpoint { current: RwLock::new(mv) }),
                );
            }
        }
        Ok(version)
    }

    /// Resolve an endpoint (None = [`DEFAULT_ENDPOINT`]) to its current
    /// version.  The returned `Arc` pins that version for as long as the
    /// caller holds it — this is the per-batch resolution point.
    pub fn resolve(&self, name: Option<&str>) -> Option<Arc<ModelVersion>> {
        // chaos site: `error`/`nan` make the endpoint vanish for this
        // resolution (workers reply with a typed rejection), `delay`
        // stretches the resolution window for swap races
        if failpoint::check("registry.resolve").is_some() {
            return None;
        }
        let name = name.unwrap_or(DEFAULT_ENDPOINT);
        let map = sync::read(&self.endpoints);
        map.get(name).map(|ep| sync::read(&ep.current).clone())
    }

    pub fn contains(&self, name: &str) -> bool {
        sync::read(&self.endpoints).contains_key(name)
    }

    pub fn is_empty(&self) -> bool {
        sync::read(&self.endpoints).is_empty()
    }

    /// (name, current version) for every endpoint, sorted by name.
    pub fn endpoints(&self) -> Vec<(String, u64)> {
        let map = sync::read(&self.endpoints);
        let mut out: Vec<(String, u64)> = map
            .iter()
            .map(|(k, ep)| (k.clone(), sync::read(&ep.current).version))
            .collect();
        out.sort();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    fn tiny_model(seed: u64) -> Arc<Model> {
        Arc::new(Model::new(
            ModelConfig { n_layers: 1, ..Default::default() },
            seed,
        ))
    }

    #[test]
    fn register_resolve_and_swap_bump_versions() {
        let r = Registry::new();
        assert!(r.resolve(None).is_none());
        let v1 = r.register(DEFAULT_ENDPOINT, tiny_model(1)).unwrap();
        let got = r.resolve(None).unwrap();
        assert_eq!(got.version, v1);
        assert_eq!(got.name, DEFAULT_ENDPOINT);
        let v2 = r.register(DEFAULT_ENDPOINT, tiny_model(2)).unwrap();
        assert!(v2 > v1, "swap must bump the version");
        assert_eq!(r.resolve(None).unwrap().version, v2);
        // the old version stays alive for whoever pinned it
        assert_eq!(got.version, v1);
    }

    #[test]
    fn named_endpoints_are_independent() {
        let r = Registry::new();
        r.register("a", tiny_model(1)).unwrap();
        let vb = r.register("b", tiny_model(2)).unwrap();
        assert!(r.contains("a") && r.contains("b"));
        assert!(!r.contains("c"));
        assert!(r.resolve(Some("c")).is_none());
        assert_eq!(r.resolve(Some("b")).unwrap().version, vb);
        let eps = r.endpoints();
        assert_eq!(eps.len(), 2);
        assert_eq!(eps[0].0, "a");
    }

    #[test]
    fn swap_is_visible_to_new_resolves_only() {
        let r = Registry::new();
        r.register(DEFAULT_ENDPOINT, tiny_model(1)).unwrap();
        let pinned = r.resolve(None).unwrap();
        let p1 = Arc::as_ptr(&pinned.model);
        r.register(DEFAULT_ENDPOINT, tiny_model(2)).unwrap();
        let fresh = r.resolve(None).unwrap();
        assert!(!std::ptr::eq(p1, Arc::as_ptr(&fresh.model)));
        // the pinned batch still sees its original model pointer
        assert!(std::ptr::eq(p1, Arc::as_ptr(&pinned.model)));
    }

    #[test]
    fn poisoned_snapshot_is_refused_and_old_version_keeps_serving() {
        let r = Registry::new();
        let v1 = r.register(DEFAULT_ENDPOINT, tiny_model(1)).unwrap();

        let mut poisoned = Model::new(
            ModelConfig { n_layers: 1, ..Default::default() },
            2,
        );
        let mid = poisoned.params.len() / 2;
        poisoned.params[mid] = f64::NAN;
        let err = r
            .register(DEFAULT_ENDPOINT, Arc::new(poisoned))
            .expect_err("NaN snapshot must be refused at promote time");
        let msg = err.to_string();
        assert!(msg.contains("non-finite"), "{msg}");
        assert!(msg.contains(&format!("parameter {mid}")), "{msg}");

        // the hot swap never happened: the live version is unchanged
        let live = r.resolve(None).unwrap();
        assert_eq!(live.version, v1);
        assert!(live.model.params.iter().all(|p| p.is_finite()));
    }
}
