//! Training loops: the AOT artifact driver ([`Trainer`]) and the fully
//! native loop ([`NativeTrainer`]).
//!
//! [`Trainer`] drives one fused XLA computation: (state..., batch...) ->
//! (state'..., loss) with Adam folded in.  Rust owns the loop, the data
//! pipeline, shuffling, and logging; Python was only the compiler.
//!
//! [`NativeTrainer`] needs no artifacts at all: it optimizes the native
//! [`Model`] (every contraction on the planned Gaunt engine) against an
//! energy + force loss with Adam (or SGD), and checkpoints to JSON
//! through `util::json`.  The trainer is layout-agnostic: parameters
//! are one flat vector whose interpretation (including multi-channel
//! `Irreps` node features, `channels > 1`) is owned entirely by the
//! model — checkpoints carry the layout in their config, so a trainer
//! resumed from JSON always rebuilds the exact same model.  The force-loss parameter gradient needs the
//! mixed second derivative d^2 E / dx dtheta; rather than a hand-rolled
//! second reverse pass, it is evaluated as a Pearlmutter-style
//! Hessian-vector product — a central difference of the EXACT analytic
//! theta-gradient along the force-residual direction — which costs two
//! extra backward passes per graph and matches the true loss gradient to
//! ~1e-10 relative (validated in `python/compile/model_golden.py --check`
//! and `tests/grad_check.rs`).

use std::sync::Arc;

use super::service::Service;
use crate::data::Graph;
use crate::err;
use crate::model::{Model, ModelScratch};
use crate::runtime::{Engine, Executable, Tensor};
use crate::util::error::Result;
use crate::util::json::Json;

/// Generic trainer over a train-step artifact.
pub struct Trainer {
    exe: Arc<Executable>,
    /// current (params + optimizer) state, artifact input order
    state: Vec<Tensor>,
    n_state: usize,
    /// loss history (one entry per step)
    pub losses: Vec<f64>,
}

impl Trainer {
    /// Load an artifact (e.g. "ff_train_step_gaunt") and its initial state
    /// blob (e.g. "ff_state_init_gaunt").
    pub fn new(engine: &Engine, artifact: &str, state_blob: &str) -> Result<Self> {
        let exe = engine.load(artifact)?;
        let n_state = exe
            .meta
            .get("n_state")
            .and_then(Json::as_usize)
            .ok_or_else(|| err!("{artifact}: meta.n_state missing"))?;
        let state: Vec<Tensor> = engine
            .load_state_blob(state_blob)?
            .into_iter()
            .map(|(_, t)| t)
            .collect();
        if state.len() != n_state {
            return Err(err!(
                "state blob has {} tensors, artifact expects {}",
                state.len(),
                n_state
            ));
        }
        Ok(Trainer { exe, state, n_state, losses: Vec::new() })
    }

    pub fn batch_size(&self) -> usize {
        self.exe
            .meta
            .get("batch")
            .and_then(Json::as_usize)
            .unwrap_or(1)
    }

    pub fn n_state(&self) -> usize {
        self.n_state
    }

    /// One optimization step; `batch` are the artifact's batch inputs in
    /// manifest order (after the state inputs).  Returns the loss.
    pub fn step(&mut self, batch: Vec<Tensor>) -> Result<f64> {
        let expected = self.exe.inputs.len() - self.n_state;
        if batch.len() != expected {
            return Err(err!(
                "step: expected {expected} batch tensors, got {}",
                batch.len()
            ));
        }
        let mut inputs = self.state.clone();
        inputs.extend(batch);
        let mut outputs = self.exe.run(&inputs)?;
        let loss_t = outputs.pop().ok_or_else(|| err!("no loss output"))?;
        let loss = loss_t.as_f32()?[0] as f64;
        if !loss.is_finite() {
            return Err(err!("non-finite loss at step {}", self.losses.len()));
        }
        self.state = outputs;
        self.losses.push(loss);
        Ok(loss)
    }

    /// Current state tensors (params + opt), artifact input order — the
    /// same prefix order the `ff_fwd_*` artifacts expect.
    pub fn state(&self) -> &[Tensor] {
        &self.state
    }

    pub fn take_state(self) -> Vec<Tensor> {
        self.state
    }

    /// Mean loss over the trailing window.
    pub fn recent_loss(&self, window: usize) -> f64 {
        mean_tail(&self.losses, window)
    }
}

/// Hyperparameters of the native training loop.
#[derive(Clone, Copy, Debug)]
pub struct NativeTrainConfig {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    /// weight of the per-atom energy MSE
    pub w_energy: f64,
    /// weight of the per-component force MSE
    pub w_force: f64,
    /// displacement of the Hessian-vector central difference
    pub fd_eps: f64,
    /// plain SGD instead of Adam
    pub sgd: bool,
}

impl Default for NativeTrainConfig {
    fn default() -> Self {
        NativeTrainConfig {
            lr: 5e-3,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            w_energy: 1.0,
            w_force: 1.0,
            fd_eps: 1e-4,
            sgd: false,
        }
    }
}

/// Native training loop over the Gaunt-engine [`Model`]: energy + force
/// loss, Adam/SGD, JSON checkpoints.  Labeled structures come straight
/// from the MD substrate ([`crate::data::Graph`]).
pub struct NativeTrainer {
    pub model: Model,
    pub cfg: NativeTrainConfig,
    /// loss history (one entry per step, evaluated pre-update)
    pub losses: Vec<f64>,
    /// Adam first/second moments
    m1: Vec<f64>,
    m2: Vec<f64>,
    steps: usize,
    scratch: ModelScratch,
    grad: Vec<f64>,
    gtmp: Vec<f64>,
    gshift: Vec<f64>,
    forces: Vec<f64>,
    ftmp: Vec<f64>,
    pos_tmp: Vec<[f64; 3]>,
}

impl NativeTrainer {
    pub fn new(model: Model, cfg: NativeTrainConfig) -> NativeTrainer {
        let n = model.n_params();
        let scratch = model.scratch();
        NativeTrainer {
            cfg,
            losses: Vec::new(),
            m1: vec![0.0; n],
            m2: vec![0.0; n],
            steps: 0,
            grad: vec![0.0; n],
            gtmp: vec![0.0; n],
            gshift: vec![0.0; n],
            forces: vec![0.0; 3 * model.cfg.max_atoms],
            ftmp: vec![0.0; 3 * model.cfg.max_atoms],
            pos_tmp: Vec::new(),
            scratch,
            model,
        }
    }

    /// Number of optimizer steps taken.
    pub fn steps(&self) -> usize {
        self.steps
    }

    /// Loss + full parameter gradient over `batch`, written into
    /// `self.grad`.  Per graph: one analytic forward+backward at the
    /// observed positions (energy term, forces, dE/dtheta) and two more
    /// theta-gradient evaluations at `x +- fd_eps * vhat` for the
    /// force-term HVP.
    fn loss_grad(&mut self, batch: &[Graph]) -> f64 {
        self.grad.fill(0.0);
        let mut loss = 0.0;
        let w_e = self.cfg.w_energy;
        let w_f = self.cfg.w_force;
        for g in batch {
            let n = g.n_atoms();
            let edges = self.model.build_edges(&g.pos);
            self.gtmp.fill(0.0);
            self.forces[..3 * n].fill(0.0);
            let e = self.model.grad_into(
                &g.pos, &g.species, &edges, &mut self.forces[..3 * n],
                &mut self.gtmp, &mut self.scratch,
            );
            // energy term: w_e ((E - E*)/n)^2
            let de = (e - g.energy) / n as f64;
            loss += w_e * de * de;
            let scale_e = 2.0 * w_e * de / n as f64;
            for (gv, tv) in self.grad.iter_mut().zip(&self.gtmp) {
                *gv += scale_e * tv;
            }
            // force term: w_f |F - F*|^2 / (3n)
            let mut vnorm2 = 0.0;
            for (i, f_ref) in g.forces.iter().enumerate() {
                for ax in 0..3 {
                    let v = self.forces[3 * i + ax] - f_ref[ax];
                    self.forces[3 * i + ax] = v; // reuse as the residual
                    vnorm2 += v * v;
                }
            }
            loss += w_f * vnorm2 / (3 * n) as f64;
            let vnorm = vnorm2.sqrt();
            if vnorm > 0.0 {
                // d(force loss)/dtheta = -2 w_f/(3n) v . d(grad_x E)/dth
                // = -2 w_f |v|/(3n) * d/deps [dE/dth](x + eps vhat):
                // central difference of the exact analytic theta-gradient
                let eps = self.cfg.fd_eps;
                let scale = 2.0 * w_f * vnorm / (3 * n) as f64;
                self.pos_tmp.clear();
                self.pos_tmp.extend_from_slice(&g.pos);
                for sign in [1.0, -1.0] {
                    for (i, p) in self.pos_tmp.iter_mut().enumerate() {
                        for ax in 0..3 {
                            p[ax] = g.pos[i][ax]
                                + sign * eps * self.forces[3 * i + ax]
                                    / vnorm;
                        }
                    }
                    self.gshift.fill(0.0);
                    self.ftmp[..3 * n].fill(0.0); // shifted forces unused
                    let _ = self.model.grad_into(
                        &self.pos_tmp, &g.species, &edges,
                        &mut self.ftmp[..3 * n], &mut self.gshift,
                        &mut self.scratch,
                    );
                    let c = -scale * sign / (2.0 * eps);
                    for (gv, sv) in self.grad.iter_mut().zip(&self.gshift) {
                        *gv += c * sv;
                    }
                }
            }
        }
        let k = batch.len().max(1) as f64;
        loss /= k;
        for gv in self.grad.iter_mut() {
            *gv /= k;
        }
        loss
    }

    /// Loss only (no optimizer update, no history entry).
    pub fn loss(&mut self, batch: &[Graph]) -> f64 {
        let mut loss = 0.0;
        let w_e = self.cfg.w_energy;
        let w_f = self.cfg.w_force;
        for g in batch {
            let n = g.n_atoms();
            let edges = self.model.build_edges(&g.pos);
            self.forces[..3 * n].fill(0.0);
            let e = self.model.energy_forces_into(
                &g.pos, &g.species, &edges, &mut self.forces[..3 * n],
                &mut self.scratch,
            );
            let de = (e - g.energy) / n as f64;
            loss += w_e * de * de;
            let mut v2 = 0.0;
            for (i, f_ref) in g.forces.iter().enumerate() {
                for ax in 0..3 {
                    let v = self.forces[3 * i + ax] - f_ref[ax];
                    v2 += v * v;
                }
            }
            loss += w_f * v2 / (3 * n) as f64;
        }
        loss / batch.len().max(1) as f64
    }

    /// Loss + full parameter gradient WITHOUT an optimizer update
    /// (diagnostics and gradient tests).
    pub fn eval_grad(&mut self, batch: &[Graph]) -> (f64, Vec<f64>) {
        let loss = self.loss_grad(batch);
        (loss, self.grad.clone())
    }

    /// One optimizer step over `batch`; returns (and records) the
    /// pre-update loss.
    pub fn step(&mut self, batch: &[Graph]) -> f64 {
        let loss = self.loss_grad(batch);
        self.steps += 1;
        if self.cfg.sgd {
            for (p, g) in self.model.params.iter_mut().zip(&self.grad) {
                *p -= self.cfg.lr * g;
            }
        } else {
            let t = self.steps as i32;
            let (b1, b2) = (self.cfg.beta1, self.cfg.beta2);
            let bc1 = 1.0 - b1.powi(t);
            let bc2 = 1.0 - b2.powi(t);
            for i in 0..self.grad.len() {
                let g = self.grad[i];
                self.m1[i] = b1 * self.m1[i] + (1.0 - b1) * g;
                self.m2[i] = b2 * self.m2[i] + (1.0 - b2) * g * g;
                let mh = self.m1[i] / bc1;
                let vh = self.m2[i] / bc2;
                self.model.params[i] -=
                    self.cfg.lr * mh / (vh.sqrt() + self.cfg.eps);
            }
        }
        self.losses.push(loss);
        loss
    }

    /// Mean loss over the trailing window.
    pub fn recent_loss(&self, window: usize) -> f64 {
        mean_tail(&self.losses, window)
    }

    /// Write the model checkpoint (config + params) to `path`.
    pub fn checkpoint(&self, path: &str) -> Result<()> {
        self.model.save(path)
    }

    /// Resume from a checkpoint written by [`NativeTrainer::checkpoint`]
    /// (fresh optimizer state).
    pub fn from_checkpoint(
        path: &str, cfg: NativeTrainConfig,
    ) -> Result<NativeTrainer> {
        Ok(NativeTrainer::new(Model::load(path)?, cfg))
    }

    /// Hand the trained model off (e.g. to the serving backend).
    pub fn into_model(self) -> Model {
        self.model
    }

    /// Immutable snapshot of the current model (config + parameters
    /// copied) — what gets promoted into a live service without
    /// stopping training.
    pub fn snapshot_model(&self) -> Model {
        self.model.snapshot()
    }

    /// Hot-promote the current parameters into a live service endpoint
    /// (the checkpoint-to-production path); returns the new registry
    /// version.  Training can keep stepping: the service serves the
    /// snapshot, not the live parameters.  A snapshot whose parameters
    /// went non-finite (diverged training) is refused and the endpoint
    /// keeps serving its previous version.
    pub fn promote_to(&self, service: &Service, name: &str) -> Result<u64> {
        service.promote(name, Arc::new(self.snapshot_model()))
    }
}

/// Mean of the last `window` entries (NaN when empty).
pub fn mean_tail(xs: &[f64], window: usize) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let k = xs.len().saturating_sub(window);
    let tail = &xs[k..];
    tail.iter().sum::<f64>() / tail.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::util::rng::Rng;

    fn tiny_batch(seed: u64) -> Vec<Graph> {
        let mut rng = Rng::new(seed);
        (0..2)
            .map(|_| {
                let n = 3;
                let pos: Vec<[f64; 3]> = (0..n)
                    .map(|_| [rng.normal(), rng.normal(), rng.normal()])
                    .collect();
                Graph {
                    species: (0..n).map(|_| rng.below(3)).collect(),
                    energy: rng.normal(),
                    forces: (0..n)
                        .map(|_| [0.1 * rng.normal(), 0.1 * rng.normal(),
                                  0.1 * rng.normal()])
                        .collect(),
                    pos,
                }
            })
            .collect()
    }

    #[test]
    fn native_step_records_the_preupdate_loss() {
        let cfg = ModelConfig { n_layers: 1, ..Default::default() };
        let mut tr = NativeTrainer::new(Model::new(cfg, 3),
                                        NativeTrainConfig::default());
        let batch = tiny_batch(0);
        let l0 = tr.loss(&batch);
        let l_step = tr.step(&batch);
        assert!((l0 - l_step).abs() < 1e-12,
                "step must report the pre-update loss");
        assert_eq!(tr.losses.len(), 1);
        assert_eq!(tr.steps(), 1);
        // the update moved the parameters
        let m2 = Model::new(cfg, 3);
        assert!(tr.model.params.iter().zip(&m2.params)
                  .any(|(a, b)| (a - b).abs() > 1e-12));
    }

    #[test]
    fn native_checkpoint_round_trip() {
        let cfg = ModelConfig { n_layers: 1, ..Default::default() };
        let tr = NativeTrainer::new(Model::new(cfg, 9),
                                    NativeTrainConfig::default());
        let path = std::env::temp_dir().join("gaunt_tp_ckpt_test.json");
        let path = path.to_str().unwrap().to_string();
        tr.checkpoint(&path).unwrap();
        let tr2 = NativeTrainer::from_checkpoint(
            &path, NativeTrainConfig::default()).unwrap();
        assert_eq!(tr.model.params, tr2.model.params);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn truncated_checkpoint_is_refused() {
        let cfg = ModelConfig { n_layers: 1, ..Default::default() };
        let tr = NativeTrainer::new(Model::new(cfg, 11),
                                    NativeTrainConfig::default());
        let path = std::env::temp_dir().join("gaunt_tp_ckpt_trunc.json");
        let path = path.to_str().unwrap().to_string();
        tr.checkpoint(&path).unwrap();
        // the atomic write leaves no temp file behind
        assert!(!std::path::Path::new(&format!("{path}.tmp")).exists());
        let text = std::fs::read_to_string(&path).unwrap();
        // chop the tail off, as a crash mid-write (without the atomic
        // temp-file + rename protocol) would
        std::fs::write(&path, &text[..text.len() * 2 / 3]).unwrap();
        let err = NativeTrainer::from_checkpoint(
            &path, NativeTrainConfig::default())
            .expect_err("truncated checkpoint must be refused");
        assert!(err.to_string().contains("Corrupt checkpoint"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn tampered_checkpoint_fails_the_checksum() {
        let cfg = ModelConfig { n_layers: 1, ..Default::default() };
        let tr = NativeTrainer::new(Model::new(cfg, 13),
                                    NativeTrainConfig::default());
        let path = std::env::temp_dir().join("gaunt_tp_ckpt_tamper.json");
        let path = path.to_str().unwrap().to_string();
        tr.checkpoint(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let cs = crate::model::params_checksum(&tr.model.params);
        assert!(text.contains(&cs), "checkpoint must embed its checksum");
        assert_ne!(cs, "0000000000000000");
        std::fs::write(&path, text.replace(&cs, "0000000000000000"))
            .unwrap();
        let err = NativeTrainer::from_checkpoint(
            &path, NativeTrainConfig::default())
            .expect_err("checksum mismatch must be refused");
        let msg = err.to_string();
        assert!(msg.contains("Corrupt checkpoint"), "{msg}");
        assert!(msg.contains("checksum mismatch"), "{msg}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn mean_tail_windows() {
        let losses = [4.0, 2.0, 2.0];
        assert!((mean_tail(&losses, 2) - 2.0).abs() < 1e-12);
        assert!((mean_tail(&losses, 10) - 8.0 / 3.0).abs() < 1e-12);
        assert!(mean_tail(&[], 3).is_nan());
    }
}
