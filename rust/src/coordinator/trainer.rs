//! Training-loop driver over a fused AOT train-step artifact.
//!
//! The artifact is one XLA computation: (state..., batch...) ->
//! (state'..., loss) with Adam folded in.  Rust owns the loop, the data
//! pipeline, shuffling, and logging; Python was only the compiler.

use std::sync::Arc;

use crate::err;
use crate::runtime::{Engine, Executable, Tensor};
use crate::util::error::Result;
use crate::util::json::Json;

/// Generic trainer over a train-step artifact.
pub struct Trainer {
    exe: Arc<Executable>,
    /// current (params + optimizer) state, artifact input order
    state: Vec<Tensor>,
    n_state: usize,
    /// loss history (one entry per step)
    pub losses: Vec<f64>,
}

impl Trainer {
    /// Load an artifact (e.g. "ff_train_step_gaunt") and its initial state
    /// blob (e.g. "ff_state_init_gaunt").
    pub fn new(engine: &Engine, artifact: &str, state_blob: &str) -> Result<Self> {
        let exe = engine.load(artifact)?;
        let n_state = exe
            .meta
            .get("n_state")
            .and_then(Json::as_usize)
            .ok_or_else(|| err!("{artifact}: meta.n_state missing"))?;
        let state: Vec<Tensor> = engine
            .load_state_blob(state_blob)?
            .into_iter()
            .map(|(_, t)| t)
            .collect();
        if state.len() != n_state {
            return Err(err!(
                "state blob has {} tensors, artifact expects {}",
                state.len(),
                n_state
            ));
        }
        Ok(Trainer { exe, state, n_state, losses: Vec::new() })
    }

    pub fn batch_size(&self) -> usize {
        self.exe
            .meta
            .get("batch")
            .and_then(Json::as_usize)
            .unwrap_or(1)
    }

    pub fn n_state(&self) -> usize {
        self.n_state
    }

    /// One optimization step; `batch` are the artifact's batch inputs in
    /// manifest order (after the state inputs).  Returns the loss.
    pub fn step(&mut self, batch: Vec<Tensor>) -> Result<f64> {
        let expected = self.exe.inputs.len() - self.n_state;
        if batch.len() != expected {
            return Err(err!(
                "step: expected {expected} batch tensors, got {}",
                batch.len()
            ));
        }
        let mut inputs = self.state.clone();
        inputs.extend(batch);
        let mut outputs = self.exe.run(&inputs)?;
        let loss_t = outputs.pop().ok_or_else(|| err!("no loss output"))?;
        let loss = loss_t.as_f32()?[0] as f64;
        if !loss.is_finite() {
            return Err(err!("non-finite loss at step {}", self.losses.len()));
        }
        self.state = outputs;
        self.losses.push(loss);
        Ok(loss)
    }

    /// Current state tensors (params + opt), artifact input order — the
    /// same prefix order the `ff_fwd_*` artifacts expect.
    pub fn state(&self) -> &[Tensor] {
        &self.state
    }

    pub fn take_state(self) -> Vec<Tensor> {
        self.state
    }

    /// Mean loss over the trailing window.
    pub fn recent_loss(&self, window: usize) -> f64 {
        mean_tail(&self.losses, window)
    }
}

/// Mean of the last `window` entries (NaN when empty).
pub fn mean_tail(xs: &[f64], window: usize) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let k = xs.len().saturating_sub(window);
    let tail = &xs[k..];
    tail.iter().sum::<f64>() / tail.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_tail_windows() {
        let losses = [4.0, 2.0, 2.0];
        assert!((mean_tail(&losses, 2) - 2.0).abs() < 1e-12);
        assert!((mean_tail(&losses, 10) - 8.0 / 3.0).abs() < 1e-12);
        assert!(mean_tail(&[], 3).is_nan());
    }
}
