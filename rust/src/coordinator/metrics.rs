//! Serving metrics: counters + a fixed-bucket latency histogram with
//! percentile estimation.  Lock-free on the hot path (atomics).
//!
//! [`MetricsSnapshot`] is the serializable (JSON) projection: a replica
//! answers a wire `stats` request with one, and the front door merges
//! the snapshots of every live replica into a fleet-wide view
//! ([`MetricsSnapshot::merge`]).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::Json;

/// Exponential latency buckets from 1 µs to ~67 s.
const N_BUCKETS: usize = 27;

pub struct LatencyHistogram {
    buckets: [AtomicU64; N_BUCKETS],
    count: AtomicU64,
    sum_ns: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
        }
    }

    fn bucket_of(ns: u64) -> usize {
        // bucket i covers [1000 * 2^i, 1000 * 2^{i+1}) ns
        let us = (ns / 1000).max(1);
        (63 - us.leading_zeros() as usize).min(N_BUCKETS - 1)
    }

    pub fn record_ns(&self, ns: u64) {
        self.buckets[Self::bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_ns(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_ns.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// Percentile estimate (upper bucket edge), q in [0, 1].
    pub fn percentile_ns(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return 1000.0 * (1u64 << (i + 1)) as f64;
            }
        }
        1000.0 * (1u64 << N_BUCKETS) as f64
    }
}

/// Aggregate service metrics.
#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub rejected: AtomicU64,
    /// requests failed with `ServiceError::Canceled`
    pub canceled: AtomicU64,
    /// requests failed with `ServiceError::DeadlineExceeded`
    pub expired: AtomicU64,
    /// requests failed at execution time (backend `Exec` errors,
    /// vanished endpoints) — together with `responses`, `rejected`,
    /// `canceled`, and `expired` this reconciles against `requests`
    /// (worker panics are the remainder, counted in `worker_panics`)
    pub failed: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    pub padding_waste: AtomicU64,
    /// atom slots executed (batch rows x bucket width), the padding
    /// denominator: `atom_fill = true_atom_slots / padded_atom_slots`
    pub padded_atom_slots: AtomicU64,
    /// occupied atom slots actually carried by those rows
    pub true_atom_slots: AtomicU64,
    /// MD frames streamed to rollout tickets
    pub frames: AtomicU64,
    /// relax tasks completed (any outcome)
    pub relaxes: AtomicU64,
    /// rollout tasks completed (any outcome)
    pub rollouts: AtomicU64,
    /// worker panics survived (requests were failed via reply-on-drop)
    pub worker_panics: AtomicU64,
    /// workers respawned by the supervisor (after a panic death or a
    /// hang detach)
    pub restarts: AtomicU64,
    /// workers the supervisor declared hung (heartbeat stale past the
    /// hang timeout) and detached
    pub hung_detected: AtomicU64,
    /// requests shed by admission control (`ServiceError::Overloaded`);
    /// every shed is also counted in `rejected`, so `requests` still
    /// reconciles
    pub shed: AtomicU64,
    /// tensor-product plans built so far (gauge, mirrored from the
    /// engine's `PlanCache` after each batch)
    pub plan_builds: AtomicU64,
    /// plan-cache read hits (gauge, mirrored)
    pub plan_hits: AtomicU64,
    /// plans currently cached (gauge, mirrored)
    pub plan_entries: AtomicU64,
    pub latency: LatencyHistogram,
    pub exec_latency: LatencyHistogram,
}

impl Metrics {
    pub fn new() -> Self {
        Default::default()
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// Mirror a plan-cache snapshot (builds/hits/cached entries) into
    /// the serving gauges.  Called by the server after each batch so a
    /// `report()` shows plan churn — a growing `plan_builds` under
    /// steady traffic means requests keep hitting cold op keys.
    pub fn observe_plans(&self, builds: u64, hits: u64, entries: u64) {
        self.plan_builds.store(builds, Ordering::Relaxed);
        self.plan_hits.store(hits, Ordering::Relaxed);
        self.plan_entries.store(entries, Ordering::Relaxed);
    }

    /// Record one executed padded chunk: `rows` occupied rows padded to
    /// `row_slots` total rows of `width` atom slots each, carrying
    /// `true_atoms` real atoms.
    pub fn observe_padding(
        &self, row_slots: u64, width: u64, true_atoms: u64,
    ) {
        self.padded_atom_slots
            .fetch_add(row_slots * width, Ordering::Relaxed);
        self.true_atom_slots.fetch_add(true_atoms, Ordering::Relaxed);
    }

    /// Fraction of executed atom slots that carried a real atom (1.0 =
    /// no padding waste at all; 0.0 before anything executed).
    pub fn atom_fill(&self) -> f64 {
        let padded = self.padded_atom_slots.load(Ordering::Relaxed);
        if padded == 0 {
            return 0.0;
        }
        self.true_atom_slots.load(Ordering::Relaxed) as f64 / padded as f64
    }

    pub fn report(&self) -> String {
        format!(
            "requests={} responses={} rejected={} canceled={} expired={} \
             failed={} shed={} batches={} mean_batch={:.2} \
             pad_waste={} atom_fill={:.2} frames={} \
             restarts={} hung={} \
             plans={}/{}built hits={} p50={:.2}ms p99={:.2}ms \
             mean={:.2}ms exec_p50={:.2}ms",
            self.requests.load(Ordering::Relaxed),
            self.responses.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.canceled.load(Ordering::Relaxed),
            self.expired.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.shed.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.padding_waste.load(Ordering::Relaxed),
            self.atom_fill(),
            self.frames.load(Ordering::Relaxed),
            self.restarts.load(Ordering::Relaxed),
            self.hung_detected.load(Ordering::Relaxed),
            self.plan_entries.load(Ordering::Relaxed),
            self.plan_builds.load(Ordering::Relaxed),
            self.plan_hits.load(Ordering::Relaxed),
            self.latency.percentile_ns(0.5) / 1e6,
            self.latency.percentile_ns(0.99) / 1e6,
            self.latency.mean_ns() / 1e6,
            self.exec_latency.percentile_ns(0.5) / 1e6,
        )
    }
}

// ---------------------------------------------------------------------
// wire-serializable snapshot
// ---------------------------------------------------------------------

/// A point-in-time copy of the counter ledger plus latency percentiles,
/// cheap to serialize and to aggregate across replicas.  The ledger
/// counters add under [`merge`](MetricsSnapshot::merge); the percentile
/// fields take the max (a fleet p99 is at least its worst member's).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub responses: u64,
    pub rejected: u64,
    pub canceled: u64,
    pub expired: u64,
    pub failed: u64,
    pub shed: u64,
    pub frames: u64,
    pub worker_panics: u64,
    pub restarts: u64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

/// (field name, is-counter) — one row per snapshot field, so to_json /
/// from_json / merge can never drift from the struct.
const SNAPSHOT_FIELDS: [(&str, bool); 12] = [
    ("requests", true),
    ("responses", true),
    ("rejected", true),
    ("canceled", true),
    ("expired", true),
    ("failed", true),
    ("shed", true),
    ("frames", true),
    ("worker_panics", true),
    ("restarts", true),
    ("p50_ns", false),
    ("p99_ns", false),
];

impl MetricsSnapshot {
    fn field(&self, name: &str) -> f64 {
        match name {
            "requests" => self.requests as f64,
            "responses" => self.responses as f64,
            "rejected" => self.rejected as f64,
            "canceled" => self.canceled as f64,
            "expired" => self.expired as f64,
            "failed" => self.failed as f64,
            "shed" => self.shed as f64,
            "frames" => self.frames as f64,
            "worker_panics" => self.worker_panics as f64,
            "restarts" => self.restarts as f64,
            "p50_ns" => self.p50_ns,
            "p99_ns" => self.p99_ns,
            _ => unreachable!("unknown snapshot field {name}"),
        }
    }

    fn set_field(&mut self, name: &str, v: f64) {
        match name {
            "requests" => self.requests = v as u64,
            "responses" => self.responses = v as u64,
            "rejected" => self.rejected = v as u64,
            "canceled" => self.canceled = v as u64,
            "expired" => self.expired = v as u64,
            "failed" => self.failed = v as u64,
            "shed" => self.shed = v as u64,
            "frames" => self.frames = v as u64,
            "worker_panics" => self.worker_panics = v as u64,
            "restarts" => self.restarts = v as u64,
            "p50_ns" => self.p50_ns = v,
            "p99_ns" => self.p99_ns = v,
            _ => unreachable!("unknown snapshot field {name}"),
        }
    }

    /// Fold another replica's snapshot into this one: counters add,
    /// percentiles take the max.
    pub fn merge(&mut self, other: &MetricsSnapshot) {
        for (name, is_counter) in SNAPSHOT_FIELDS {
            let v = if is_counter {
                self.field(name) + other.field(name)
            } else {
                self.field(name).max(other.field(name))
            };
            self.set_field(name, v);
        }
    }

    /// `requests = responses + failed + canceled + expired` — whether
    /// this ledger accounts for every admitted request (rejected/shed
    /// submissions were never counted in `requests`).
    pub fn reconciles(&self) -> bool {
        self.requests
            == self.responses + self.failed + self.canceled + self.expired
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(
            SNAPSHOT_FIELDS
                .iter()
                .map(|(name, _)| (name.to_string(), Json::Num(self.field(name))))
                .collect(),
        )
    }

    /// Decode a snapshot; unknown keys are ignored, missing keys read
    /// as zero (forward/backward compatible across replica versions).
    pub fn from_json(v: &Json) -> Result<MetricsSnapshot, String> {
        let obj = v.as_obj().ok_or("metrics snapshot must be an object")?;
        let mut s = MetricsSnapshot::default();
        for (name, _) in SNAPSHOT_FIELDS {
            if let Some(x) = obj.get(name) {
                let n = x
                    .as_f64()
                    .ok_or_else(|| format!("snapshot field '{name}' not a number"))?;
                if !n.is_finite() || n < 0.0 {
                    return Err(format!(
                        "snapshot field '{name}' out of range: {n}"
                    ));
                }
                s.set_field(name, n);
            }
        }
        Ok(s)
    }
}

impl Metrics {
    /// Copy the ledger counters + latency percentiles into a
    /// serializable snapshot.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            responses: self.responses.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            canceled: self.canceled.load(Ordering::Relaxed),
            expired: self.expired.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            frames: self.frames.load(Ordering::Relaxed),
            worker_panics: self.worker_panics.load(Ordering::Relaxed),
            restarts: self.restarts.load(Ordering::Relaxed),
            p50_ns: self.latency.percentile_ns(0.5),
            p99_ns: self.latency.percentile_ns(0.99),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles_ordered() {
        let h = LatencyHistogram::new();
        for i in 1..=1000u64 {
            h.record_ns(i * 10_000); // 10µs .. 10ms
        }
        let p50 = h.percentile_ns(0.5);
        let p99 = h.percentile_ns(0.99);
        assert!(p50 <= p99);
        assert!(p50 >= 1e6 && p50 <= 2e7, "p50 {p50}");
        assert_eq!(h.count(), 1000);
    }

    #[test]
    fn mean_is_exact() {
        let h = LatencyHistogram::new();
        h.record_ns(1_000_000);
        h.record_ns(3_000_000);
        assert!((h.mean_ns() - 2e6).abs() < 1.0);
    }

    #[test]
    fn empty_histogram() {
        let h = LatencyHistogram::new();
        assert_eq!(h.percentile_ns(0.5), 0.0);
        assert_eq!(h.mean_ns(), 0.0);
    }

    #[test]
    fn metrics_report_renders() {
        let m = Metrics::new();
        m.requests.fetch_add(10, Ordering::Relaxed);
        m.batches.fetch_add(2, Ordering::Relaxed);
        m.batched_requests.fetch_add(10, Ordering::Relaxed);
        m.observe_plans(4, 123, 4);
        let r = m.report();
        assert!(r.contains("requests=10"));
        assert!(r.contains("mean_batch=5.00"));
        assert!(r.contains("plans=4/4built hits=123"), "{r}");
        m.restarts.fetch_add(2, Ordering::Relaxed);
        m.hung_detected.fetch_add(1, Ordering::Relaxed);
        m.shed.fetch_add(3, Ordering::Relaxed);
        let r = m.report();
        assert!(r.contains("restarts=2"), "{r}");
        assert!(r.contains("hung=1"), "{r}");
        assert!(r.contains("shed=3"), "{r}");
    }

    #[test]
    fn atom_fill_tracks_padding() {
        let m = Metrics::new();
        assert_eq!(m.atom_fill(), 0.0);
        // 4 rows padded to 8 atoms each, carrying 16 real atoms
        m.observe_padding(4, 8, 16);
        assert!((m.atom_fill() - 0.5).abs() < 1e-12);
        let r = m.report();
        assert!(r.contains("atom_fill=0.50"), "{r}");
    }

    #[test]
    fn observe_plans_is_a_gauge_not_a_counter() {
        let m = Metrics::new();
        m.observe_plans(2, 10, 2);
        m.observe_plans(3, 50, 3);
        assert_eq!(m.plan_builds.load(Ordering::Relaxed), 3);
        assert_eq!(m.plan_hits.load(Ordering::Relaxed), 50);
        assert_eq!(m.plan_entries.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn snapshot_roundtrips_through_json() {
        let m = Metrics::new();
        m.requests.fetch_add(10, Ordering::Relaxed);
        m.responses.fetch_add(7, Ordering::Relaxed);
        m.failed.fetch_add(2, Ordering::Relaxed);
        m.canceled.fetch_add(1, Ordering::Relaxed);
        m.shed.fetch_add(3, Ordering::Relaxed);
        m.latency.record_ns(2_000_000);
        let s = m.snapshot();
        assert!(s.reconciles(), "{s:?}");
        let re = MetricsSnapshot::from_json(&s.to_json()).unwrap();
        assert_eq!(s, re);
        // malformed documents are typed errors, not panics
        assert!(MetricsSnapshot::from_json(&Json::Num(1.0)).is_err());
        assert!(MetricsSnapshot::from_json(&Json::obj(vec![(
            "requests",
            Json::Str("x".into())
        )]))
        .is_err());
    }

    #[test]
    fn snapshot_merge_adds_counters_and_maxes_percentiles() {
        let mut a = MetricsSnapshot {
            requests: 5,
            responses: 5,
            p50_ns: 1e6,
            p99_ns: 3e6,
            ..Default::default()
        };
        let b = MetricsSnapshot {
            requests: 3,
            responses: 2,
            failed: 1,
            p50_ns: 2e6,
            p99_ns: 2e6,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.requests, 8);
        assert_eq!(a.responses, 7);
        assert_eq!(a.failed, 1);
        assert_eq!(a.p50_ns, 2e6);
        assert_eq!(a.p99_ns, 3e6);
        assert!(a.reconciles());
    }
}
