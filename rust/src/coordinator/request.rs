//! The typed multi-task serving protocol.
//!
//! A request is a [`Task`] (what to compute) wrapped in a [`Request`]
//! (how to serve it: deadline, model endpoint).  Submitting through
//! [`crate::coordinator::service::Client`] returns a [`Ticket`] — a
//! non-blocking, typed handle with `wait`/`try_poll`/`cancel` and, for
//! streaming tasks, `next_frame`.
//!
//! **Reply-on-drop guarantee.**  Every queued request owns a
//! [`ReplySlot`]; if the slot is dropped before a reply was sent — a
//! worker panicked mid-batch, the queue was closed while the request was
//! still pending, a batch errored — the slot's `Drop` sends
//! [`ServiceError::Dropped`], so a caller blocked in [`Ticket::wait`]
//! can NEVER hang.  The legacy [`Envelope`] carries the same guarantee
//! through [`ReplyGuard`] (the original protocol leaked a
//! forever-blocked `rx.recv()` whenever an envelope died between
//! `submit` and the reply send).
//!
//! Typing is per task: each request struct ([`EnergyOnly`],
//! [`EnergyForces`], [`Relax`], [`MdRollout`], [`Batch`]) implements
//! [`TaskSpec`], which fixes the output type its ticket decodes to —
//! submitting a `Relax` gives a `Ticket` that waits into a
//! [`RelaxResult`], not a stringly-typed blob.

use std::collections::VecDeque;
use std::marker::PhantomData;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::md::relax::RelaxResult;

// ---------------------------------------------------------------------
// errors
// ---------------------------------------------------------------------

/// Why task execution failed — the typed payload of
/// [`ServiceError::Exec`], so callers can tell an infrastructure fault
/// (retry elsewhere) from a numerically diverged input (don't retry).
#[derive(Clone, Debug, PartialEq)]
pub enum ExecFault {
    /// The backend (or model resolution inside it) returned an error.
    Backend(String),
    /// The computed energies/forces/frames contained NaN or infinity;
    /// the offending structure was quarantined at the worker boundary
    /// before it could contaminate batchmates or stream onward.
    NonFinite(String),
    /// A long task (Relax/MdRollout) exhausted its runtime step/force-
    /// evaluation budget without finishing.
    BudgetExhausted(String),
}

impl std::fmt::Display for ExecFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecFault::Backend(m) => write!(f, "{m}"),
            ExecFault::NonFinite(m) => write!(f, "non-finite output: {m}"),
            ExecFault::BudgetExhausted(m) => {
                write!(f, "step budget exhausted: {m}")
            }
        }
    }
}

/// Typed service errors — every way a request can fail to produce its
/// task's output.
#[derive(Clone, Debug, PartialEq)]
pub enum ServiceError {
    /// Refused at submit time (validation, backpressure, unknown model,
    /// structure larger than the largest bucket).
    Rejected(String),
    /// Shed by admission control: the service is over its queue-depth
    /// watermark and this task's priority class is being dropped first.
    /// Retryable — back off at least `retry_after` (see
    /// `Client::submit_with_retry`).
    Overloaded { retry_after: Duration },
    /// The per-request deadline passed before the task finished.
    DeadlineExceeded,
    /// The caller canceled the ticket.
    Canceled,
    /// The service was shut down while the request was still queued.
    Shutdown,
    /// The request's reply slot was dropped without a reply (worker
    /// panic or channel teardown) — the reply-on-drop guarantee turned a
    /// would-be hang into this error.
    Dropped(String),
    /// Task execution failed (see [`ExecFault`] for the typed cause).
    Exec(ExecFault),
    /// The worker replied with a different task's reply shape (protocol
    /// bug; should be unreachable).
    Protocol(String),
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::Rejected(m) => write!(f, "rejected: {m}"),
            ServiceError::Overloaded { retry_after } => write!(
                f,
                "overloaded: shed by admission control, retry after \
                 {:.0} ms",
                retry_after.as_secs_f64() * 1e3
            ),
            ServiceError::DeadlineExceeded => write!(f, "deadline exceeded"),
            ServiceError::Canceled => write!(f, "canceled by caller"),
            ServiceError::Shutdown => {
                write!(f, "service shut down while the request was queued")
            }
            ServiceError::Dropped(m) => {
                write!(f, "dropped without a reply: {m}")
            }
            ServiceError::Exec(m) => write!(f, "execution failed: {m}"),
            ServiceError::Protocol(m) => write!(f, "protocol error: {m}"),
        }
    }
}

impl std::error::Error for ServiceError {}

// ---------------------------------------------------------------------
// tasks
// ---------------------------------------------------------------------

/// One atomic structure (positions + species), the unit every task is
/// built from.
#[derive(Clone, Debug)]
pub struct Structure {
    pub pos: Vec<[f64; 3]>,
    pub species: Vec<usize>,
}

impl Structure {
    pub fn new(pos: Vec<[f64; 3]>, species: Vec<usize>) -> Structure {
        Structure { pos, species }
    }

    pub fn n_atoms(&self) -> usize {
        self.pos.len()
    }
}

/// Most structures one [`Task::Batch`] may carry.  Backpressure counts
/// queued *requests*, so an unbounded batch could smuggle arbitrary
/// work (and memory) past every `max_queue` cap as one entry; larger
/// workloads split into multiple `Batch` submissions.
pub const MAX_BATCH_STRUCTURES: usize = 256;

/// Hard cap on `Relax::max_steps` — a step-budget watchdog so one
/// runaway relaxation cannot monopolize a worker forever.
pub const MAX_RELAX_STEPS: usize = 1_000_000;

/// Hard cap on `MdRollout::steps` (rollouts are cancellable mid-flight,
/// so the cap is generous, but it must exist: a `usize::MAX`-step
/// rollout is a worker-forever bug, not a workload).
pub const MAX_ROLLOUT_STEPS: usize = 10_000_000;

/// The wire-level task enum every request lowers to.
#[derive(Clone, Debug)]
pub enum Task {
    /// Invariant energy only — the smallest reply payload (the backend
    /// pass still computes forces; an energy-only fast path through
    /// `Model::energy_into` is future work).
    EnergyOnly { structure: Structure },
    /// Energy + forces — the classic `ForceRequest` workload.
    EnergyForces { structure: Structure },
    /// FIRE relaxation on the served surface.
    Relax { structure: Structure, max_steps: usize },
    /// NVE rollout on the served surface, streaming one [`Frame`] per
    /// step.
    MdRollout { structure: Structure, steps: usize, dt: f64 },
    /// Multi-structure submission, evaluated as one (or a few) padded
    /// batches and answered atomically.
    Batch { structures: Vec<Structure> },
}

impl Task {
    /// The structures this task evaluates (batch rows in order).
    pub fn structures(&self) -> Vec<&Structure> {
        match self {
            Task::EnergyOnly { structure }
            | Task::EnergyForces { structure }
            | Task::Relax { structure, .. }
            | Task::MdRollout { structure, .. } => vec![structure],
            Task::Batch { structures } => structures.iter().collect(),
        }
    }

    /// Largest structure in the task — what picks the shape bucket.
    pub fn n_atoms_max(&self) -> usize {
        self.structures().iter().map(|s| s.n_atoms()).max().unwrap_or(0)
    }

    /// Short label for metrics/logs.
    pub fn label(&self) -> &'static str {
        match self {
            Task::EnergyOnly { .. } => "energy",
            Task::EnergyForces { .. } => "energy_forces",
            Task::Relax { .. } => "relax",
            Task::MdRollout { .. } => "md_rollout",
            Task::Batch { .. } => "batch",
        }
    }

    /// Admission-control priority class: lower classes are shed first
    /// when the service is over its queue watermarks.  Bulk batch work
    /// (0) goes before interactive single evaluations (1); streaming
    /// long tasks (2) are shed last — they are the most expensive to
    /// restart client-side.
    pub fn priority(&self) -> u8 {
        match self {
            Task::Batch { .. } => 0,
            Task::EnergyOnly { .. } | Task::EnergyForces { .. } => 1,
            Task::Relax { .. } | Task::MdRollout { .. } => 2,
        }
    }

    /// Structural validation, done once at submit time so workers only
    /// ever see well-formed tasks.
    pub fn validate(&self) -> Result<(), String> {
        fn check(st: &Structure) -> Result<(), String> {
            if st.pos.is_empty() {
                return Err("structure has no atoms".to_string());
            }
            if st.pos.len() != st.species.len() {
                return Err(format!(
                    "structure has {} atoms but {} species",
                    st.pos.len(),
                    st.species.len()
                ));
            }
            Ok(())
        }
        match self {
            Task::EnergyOnly { structure }
            | Task::EnergyForces { structure } => check(structure),
            Task::Relax { structure, max_steps } => {
                check(structure)?;
                if *max_steps == 0 {
                    return Err("relax needs max_steps >= 1".to_string());
                }
                if *max_steps > MAX_RELAX_STEPS {
                    return Err(format!(
                        "relax max_steps {max_steps} exceeds the \
                         {MAX_RELAX_STEPS}-step budget"
                    ));
                }
                Ok(())
            }
            Task::MdRollout { structure, steps, dt } => {
                check(structure)?;
                if *steps == 0 {
                    return Err("rollout needs steps >= 1".to_string());
                }
                if *steps > MAX_ROLLOUT_STEPS {
                    return Err(format!(
                        "rollout steps {steps} exceeds the \
                         {MAX_ROLLOUT_STEPS}-step budget"
                    ));
                }
                if !dt.is_finite() || *dt <= 0.0 {
                    return Err(format!("rollout needs a finite dt > 0, got {dt}"));
                }
                Ok(())
            }
            Task::Batch { structures } => {
                if structures.is_empty() {
                    return Err("batch submission with zero structures".into());
                }
                if structures.len() > MAX_BATCH_STRUCTURES {
                    return Err(format!(
                        "batch submission with {} structures exceeds the \
                         {MAX_BATCH_STRUCTURES}-structure cap; split it \
                         into multiple Batch requests",
                        structures.len()
                    ));
                }
                for st in structures {
                    check(st)?;
                }
                Ok(())
            }
        }
    }
}

// ---------------------------------------------------------------------
// replies
// ---------------------------------------------------------------------

/// Energy-only reply payload.
#[derive(Clone, Debug)]
pub struct EnergyOut {
    pub id: u64,
    pub energy: f64,
    /// queueing + execution latency in seconds
    pub latency_s: f64,
}

/// The model's energy+forces answer (also the legacy response type).
#[derive(Clone, Debug)]
pub struct ForceResponse {
    pub id: u64,
    pub energy: f64,
    pub forces: Vec<[f64; 3]>,
    /// queueing + execution latency in seconds
    pub latency_s: f64,
}

/// One streamed MD frame.
#[derive(Clone, Debug)]
pub struct Frame {
    pub step: usize,
    /// simulation time (step + 1) * dt
    pub time: f64,
    /// potential energy after the step
    pub energy: f64,
    pub kinetic: f64,
    pub pos: Vec<[f64; 3]>,
}

/// Final summary of a rollout (frames were streamed separately).
#[derive(Clone, Debug)]
pub struct RolloutSummary {
    pub id: u64,
    /// steps actually integrated
    pub steps: usize,
    pub final_pos: Vec<[f64; 3]>,
    /// total (kinetic + potential) energy at the end
    pub final_energy: f64,
}

/// A rollout ticket's decoded output: the streamed frames (whatever the
/// caller did not already drain through [`Ticket::next_frame`]) plus the
/// summary.
#[derive(Clone, Debug)]
pub struct Trajectory {
    pub frames: Vec<Frame>,
    pub summary: RolloutSummary,
}

/// The wire-level reply enum (the typed counterpart of [`Task`]).
#[derive(Clone, Debug)]
pub enum Reply {
    Energy(EnergyOut),
    EnergyForces(ForceResponse),
    Relaxed(RelaxResult),
    Rollout(RolloutSummary),
    Batch(Vec<ForceResponse>),
}

/// What travels over a ticket's channel: zero or more frames, then
/// exactly one final message.
#[derive(Debug)]
pub enum ReplyMsg {
    Frame(Frame),
    Done(Result<Reply, ServiceError>),
}

// ---------------------------------------------------------------------
// the reply slot (reply-on-drop)
// ---------------------------------------------------------------------

/// The server half of a ticket.  Guarantees exactly one final message:
/// explicit via [`ReplySlot::finish`], or [`ServiceError::Dropped`] from
/// `Drop` if the slot dies unreplied (worker panic, queue teardown).
#[derive(Debug)]
pub struct ReplySlot {
    tx: Option<Sender<ReplyMsg>>,
}

impl ReplySlot {
    pub fn new(tx: Sender<ReplyMsg>) -> ReplySlot {
        ReplySlot { tx: Some(tx) }
    }

    /// Stream one frame (no-op after `finish`; send errors — the caller
    /// dropped its ticket — are ignored).
    pub fn frame(&self, f: Frame) {
        if let Some(tx) = &self.tx {
            let _ = tx.send(ReplyMsg::Frame(f));
        }
    }

    /// Send the final reply; subsequent calls (and the drop guard) are
    /// no-ops.
    pub fn finish(&mut self, r: Result<Reply, ServiceError>) {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(ReplyMsg::Done(r));
        }
    }

    pub fn replied(&self) -> bool {
        self.tx.is_none()
    }
}

impl Drop for ReplySlot {
    fn drop(&mut self) {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(ReplyMsg::Done(Err(ServiceError::Dropped(
                "reply slot dropped before a reply was sent (worker \
                 failure or queue teardown)"
                    .to_string(),
            ))));
        }
    }
}

// ---------------------------------------------------------------------
// pending (the queued form of a request)
// ---------------------------------------------------------------------

/// A submitted request as it sits in a bucket queue: task + serving
/// context + the reply slot.
#[derive(Debug)]
pub struct Pending {
    pub id: u64,
    pub task: Task,
    /// registry endpoint name (`None` = the default endpoint)
    pub model: Option<String>,
    pub enqueued: Instant,
    pub deadline: Option<Instant>,
    pub cancel: Arc<AtomicBool>,
    pub reply: ReplySlot,
}

impl Pending {
    pub fn n_atoms(&self) -> usize {
        self.task.n_atoms_max()
    }

    pub fn canceled(&self) -> bool {
        self.cancel.load(Ordering::Relaxed)
    }

    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.map_or(false, |d| now >= d)
    }

    /// Consume the pending with a final reply.
    pub fn finish(mut self, r: Result<Reply, ServiceError>) {
        self.reply.finish(r);
    }
}

// ---------------------------------------------------------------------
// typed request specs
// ---------------------------------------------------------------------

/// A typed task: lowers to a [`Task`] and fixes how its ticket decodes
/// the final [`Reply`].
pub trait TaskSpec: Send + 'static {
    type Output;
    /// Whether resubmitting this task after an ambiguous failure is
    /// safe.  Pure evaluations are; streaming rollouts are not (a retry
    /// would re-stream frames the caller may already have consumed).
    /// `Client::submit_with_retry` refuses to retry non-idempotent
    /// specs.
    const IDEMPOTENT: bool = true;
    fn into_task(self) -> Task;
    fn decode(
        reply: Reply, frames: Vec<Frame>,
    ) -> Result<Self::Output, ServiceError>;
}

fn protocol_mismatch<O>(want: &str, got: &Reply) -> Result<O, ServiceError> {
    Err(ServiceError::Protocol(format!(
        "expected a {want} reply, got {got:?}"
    )))
}

/// Energy only.
#[derive(Clone)]
pub struct EnergyOnly(pub Structure);

impl TaskSpec for EnergyOnly {
    type Output = EnergyOut;
    fn into_task(self) -> Task {
        Task::EnergyOnly { structure: self.0 }
    }
    fn decode(reply: Reply, _f: Vec<Frame>) -> Result<EnergyOut, ServiceError> {
        match reply {
            Reply::Energy(e) => Ok(e),
            other => protocol_mismatch("Energy", &other),
        }
    }
}

/// Energy + forces.
#[derive(Clone)]
pub struct EnergyForces(pub Structure);

impl TaskSpec for EnergyForces {
    type Output = ForceResponse;
    fn into_task(self) -> Task {
        Task::EnergyForces { structure: self.0 }
    }
    fn decode(
        reply: Reply, _f: Vec<Frame>,
    ) -> Result<ForceResponse, ServiceError> {
        match reply {
            Reply::EnergyForces(r) => Ok(r),
            other => protocol_mismatch("EnergyForces", &other),
        }
    }
}

/// FIRE relaxation served as a task.
#[derive(Clone)]
pub struct Relax {
    pub structure: Structure,
    pub max_steps: usize,
}

impl TaskSpec for Relax {
    type Output = RelaxResult;
    fn into_task(self) -> Task {
        Task::Relax { structure: self.structure, max_steps: self.max_steps }
    }
    fn decode(
        reply: Reply, _f: Vec<Frame>,
    ) -> Result<RelaxResult, ServiceError> {
        match reply {
            Reply::Relaxed(r) => Ok(r),
            other => protocol_mismatch("Relaxed", &other),
        }
    }
}

/// Streaming NVE rollout served as a task.  Not idempotent: frames are
/// streamed as they are computed, so a blind resubmission could hand
/// the caller duplicated trajectory prefixes.
#[derive(Clone)]
pub struct MdRollout {
    pub structure: Structure,
    pub steps: usize,
    pub dt: f64,
}

impl TaskSpec for MdRollout {
    type Output = Trajectory;
    const IDEMPOTENT: bool = false;
    fn into_task(self) -> Task {
        Task::MdRollout {
            structure: self.structure,
            steps: self.steps,
            dt: self.dt,
        }
    }
    fn decode(
        reply: Reply, frames: Vec<Frame>,
    ) -> Result<Trajectory, ServiceError> {
        match reply {
            Reply::Rollout(summary) => Ok(Trajectory { frames, summary }),
            other => protocol_mismatch("Rollout", &other),
        }
    }
}

/// Multi-structure batch submission.
#[derive(Clone)]
pub struct Batch(pub Vec<Structure>);

impl TaskSpec for Batch {
    type Output = Vec<ForceResponse>;
    fn into_task(self) -> Task {
        Task::Batch { structures: self.0 }
    }
    fn decode(
        reply: Reply, _f: Vec<Frame>,
    ) -> Result<Vec<ForceResponse>, ServiceError> {
        match reply {
            Reply::Batch(rs) => Ok(rs),
            other => protocol_mismatch("Batch", &other),
        }
    }
}

/// A typed request: the task payload plus serving options.
pub struct Request<T: TaskSpec> {
    pub payload: T,
    /// relative deadline, measured from submit
    pub deadline: Option<Duration>,
    /// registry endpoint name (`None` = the default endpoint)
    pub model: Option<String>,
}

impl<T: TaskSpec + Clone> Clone for Request<T> {
    fn clone(&self) -> Self {
        Request {
            payload: self.payload.clone(),
            deadline: self.deadline,
            model: self.model.clone(),
        }
    }
}

impl<T: TaskSpec> Request<T> {
    pub fn new(payload: T) -> Request<T> {
        Request { payload, deadline: None, model: None }
    }

    /// Fail the request with [`ServiceError::DeadlineExceeded`] if it
    /// has not finished within `d` of submission.
    pub fn deadline(mut self, d: Duration) -> Self {
        self.deadline = Some(d);
        self
    }

    /// Route to a named registry endpoint instead of the default model.
    pub fn model(mut self, name: impl Into<String>) -> Self {
        self.model = Some(name.into());
        self
    }
}

// ---------------------------------------------------------------------
// the ticket (client handle)
// ---------------------------------------------------------------------

/// The untyped half of a ticket: the reply channel plus the cooperative
/// cancel flag, with no compile-time output type.  This is what the wire
/// path (`net::replica`) holds for a remotely submitted task — the
/// replica pumps `rx` into wire frames without ever knowing which
/// `TaskSpec` the far-end client used, and stores `cancel` so a wire
/// `cancel` message (or the connection dying) releases the server-side
/// task.  [`Ticket::from_raw`] upgrades one into the typed handle.
///
/// Unlike [`Ticket`], dropping a `RawTicket` does NOT cancel: the
/// replica's connection handler owns explicit cancellation (per-seq
/// cancel messages, cancel-all on teardown), and an implicit
/// drop-cancel would race the forwarder thread's normal exit.
#[derive(Debug)]
pub struct RawTicket {
    pub id: u64,
    pub rx: Receiver<ReplyMsg>,
    pub cancel: Arc<AtomicBool>,
}

impl RawTicket {
    /// Build the (raw ticket, pending) pair for one submission.
    pub fn make(
        id: u64, task: Task, model: Option<String>,
        deadline: Option<Duration>,
    ) -> (RawTicket, Pending) {
        let (tx, rx) = channel();
        let cancel = Arc::new(AtomicBool::new(false));
        let now = Instant::now();
        let pending = Pending {
            id,
            task,
            model,
            enqueued: now,
            deadline: deadline.map(|d| now + d),
            cancel: cancel.clone(),
            reply: ReplySlot::new(tx),
        };
        (RawTicket { id, rx, cancel }, pending)
    }

    /// Request cooperative cancellation.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Relaxed);
    }
}

/// The non-blocking client handle for one submitted request.
///
/// `wait` blocks for the typed output; `try_poll` is its non-blocking
/// sibling; `next_frame` consumes streamed frames one at a time (for
/// [`MdRollout`]); `cancel` requests cooperative cancellation (workers
/// check between batches and between relax/MD steps).  Dropping the
/// ticket also cancels.
pub struct Ticket<T: TaskSpec> {
    pub id: u64,
    rx: Receiver<ReplyMsg>,
    cancel: Arc<AtomicBool>,
    frames: VecDeque<Frame>,
    done: Option<Result<Reply, ServiceError>>,
    /// the final result was already handed out through `try_poll`
    delivered: bool,
    _spec: PhantomData<fn() -> T>,
}

impl<T: TaskSpec> Ticket<T> {
    /// Build the (ticket, pending) pair for one submission.
    pub(crate) fn make(
        id: u64, task: Task, model: Option<String>,
        deadline: Option<Duration>,
    ) -> (Ticket<T>, Pending) {
        let (raw, pending) = RawTicket::make(id, task, model, deadline);
        (Ticket::from_raw(raw), pending)
    }

    /// Type an untyped handle.  The caller asserts the far end will
    /// answer with `T`'s reply shape; a mismatch decodes into
    /// [`ServiceError::Protocol`], never a panic.
    pub fn from_raw(raw: RawTicket) -> Ticket<T> {
        Ticket {
            id: raw.id,
            rx: raw.rx,
            cancel: raw.cancel,
            frames: VecDeque::new(),
            done: None,
            delivered: false,
            _spec: PhantomData,
        }
    }

    /// Request cooperative cancellation.  The final reply becomes
    /// [`ServiceError::Canceled`] unless the task already completed.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Relaxed);
    }

    fn absorb(&mut self, msg: ReplyMsg) {
        match msg {
            ReplyMsg::Frame(f) => self.frames.push_back(f),
            ReplyMsg::Done(r) => self.done = Some(r),
        }
    }

    fn disconnected(&mut self) {
        if self.done.is_none() {
            self.done = Some(Err(ServiceError::Dropped(
                "reply channel closed without a final message".to_string(),
            )));
        }
    }

    /// Block until the final reply and decode it into the task's typed
    /// output.  Never hangs on a dead worker: the reply-on-drop guard
    /// turns worker failure into [`ServiceError::Dropped`].
    pub fn wait(mut self) -> Result<T::Output, ServiceError> {
        if self.delivered {
            return Err(ServiceError::Protocol(
                "result already taken through try_poll".to_string(),
            ));
        }
        while self.done.is_none() {
            match self.rx.recv() {
                Ok(msg) => self.absorb(msg),
                Err(_) => self.disconnected(),
            }
        }
        let reply = self.done.take().unwrap()?;
        T::decode(reply, Vec::from(std::mem::take(&mut self.frames)))
    }

    /// Non-blocking poll: `None` while the task is still in flight,
    /// `Some(result)` exactly once; later calls return `None` again
    /// (the result was consumed).
    pub fn try_poll(&mut self) -> Option<Result<T::Output, ServiceError>> {
        if self.delivered {
            return None;
        }
        loop {
            match self.rx.try_recv() {
                Ok(msg) => self.absorb(msg),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    self.disconnected();
                    break;
                }
            }
        }
        let done = self.done.take()?;
        self.delivered = true;
        Some(match done {
            Ok(reply) => {
                T::decode(reply, Vec::from(std::mem::take(&mut self.frames)))
            }
            Err(e) => Err(e),
        })
    }

    /// Blocking frame stream: `Some(frame)` per streamed frame, `None`
    /// once the final reply arrived (which [`Ticket::wait`] then
    /// returns without blocking).
    pub fn next_frame(&mut self) -> Option<Frame> {
        if let Some(f) = self.frames.pop_front() {
            return Some(f);
        }
        if self.done.is_some() || self.delivered {
            return None;
        }
        loop {
            match self.rx.recv() {
                Ok(ReplyMsg::Frame(f)) => return Some(f),
                Ok(ReplyMsg::Done(r)) => {
                    self.done = Some(r);
                    return None;
                }
                Err(_) => {
                    self.disconnected();
                    return None;
                }
            }
        }
    }
}

impl<T: TaskSpec> Drop for Ticket<T> {
    fn drop(&mut self) {
        // an abandoned ticket should not keep burning worker time
        self.cancel.store(true, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------
// legacy single-call protocol (compatibility layer)
// ---------------------------------------------------------------------

/// A single-structure inference request (legacy protocol).
#[derive(Clone, Debug)]
pub struct ForceRequest {
    pub id: u64,
    pub pos: Vec<[f64; 3]>,
    pub species: Vec<usize>,
}

/// One-shot reply sender with the reply-on-drop guarantee: if the guard
/// dies unreplied (worker panic, batch error, queue close), `Drop`
/// sends `Err` so the paired `rx.recv()` returns instead of blocking
/// forever.
#[derive(Debug)]
pub struct ReplyGuard {
    tx: Option<Sender<Result<ForceResponse, String>>>,
}

impl ReplyGuard {
    pub fn new(tx: Sender<Result<ForceResponse, String>>) -> ReplyGuard {
        ReplyGuard { tx: Some(tx) }
    }

    /// Send the reply; at most one send wins, later calls are no-ops.
    pub fn send(&mut self, r: Result<ForceResponse, String>) {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(r);
        }
    }

    pub fn replied(&self) -> bool {
        self.tx.is_none()
    }
}

impl Drop for ReplyGuard {
    fn drop(&mut self) {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(Err(
                "request dropped without a reply (worker failure or \
                 shutdown)"
                    .to_string(),
            ));
        }
    }
}

/// Internal envelope: request + guarded reply channel + enqueue
/// timestamp (legacy protocol).
#[derive(Debug)]
pub struct Envelope {
    pub req: ForceRequest,
    pub reply: ReplyGuard,
    pub enqueued: Instant,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn structure(n: usize) -> Structure {
        Structure {
            pos: (0..n).map(|i| [i as f64, 0.0, 0.0]).collect(),
            species: vec![0; n],
        }
    }

    #[test]
    fn envelope_reply_round_trip() {
        let (tx, rx) = channel();
        let mut env = Envelope {
            req: ForceRequest { id: 7, pos: vec![[0.0; 3]], species: vec![0] },
            reply: ReplyGuard::new(tx),
            enqueued: Instant::now(),
        };
        env.reply.send(Ok(ForceResponse {
            id: env.req.id,
            energy: -1.0,
            forces: vec![[0.0; 3]],
            latency_s: 0.001,
        }));
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.id, 7);
    }

    #[test]
    fn dropped_envelope_sends_err_instead_of_hanging() {
        // the client-hang regression: an envelope that dies between
        // submit and reply (worker panic, close with a non-empty queue)
        // must fail the caller's recv(), not leak it forever
        let (tx, rx) = channel();
        let env = Envelope {
            req: ForceRequest { id: 1, pos: vec![[0.0; 3]], species: vec![0] },
            reply: ReplyGuard::new(tx),
            enqueued: Instant::now(),
        };
        drop(env);
        let got = rx.recv().expect("drop must send, not disconnect");
        assert!(got.is_err(), "drop must reply with Err");
        assert!(got.unwrap_err().contains("dropped"));
    }

    #[test]
    fn reply_guard_sends_at_most_once() {
        let (tx, rx) = channel();
        let mut g = ReplyGuard::new(tx);
        g.send(Err("first".into()));
        g.send(Err("second".into()));
        drop(g);
        assert!(rx.recv().unwrap().unwrap_err().contains("first"));
        assert!(rx.recv().is_err(), "exactly one message total");
    }

    #[test]
    fn reply_slot_drop_fails_the_ticket() {
        let (ticket, pending) =
            Ticket::<EnergyForces>::make(3, Task::EnergyForces {
                structure: structure(2),
            }, None, None);
        drop(pending);
        match ticket.wait() {
            Err(ServiceError::Dropped(_)) => {}
            other => panic!("expected Dropped, got {other:?}"),
        }
    }

    #[test]
    fn ticket_try_poll_and_frames() {
        let (mut ticket, mut pending) = Ticket::<MdRollout>::make(
            9,
            Task::MdRollout { structure: structure(2), steps: 2, dt: 0.1 },
            None,
            None,
        );
        assert!(ticket.try_poll().is_none(), "still in flight");
        pending.reply.frame(Frame {
            step: 0,
            time: 0.1,
            energy: -1.0,
            kinetic: 0.5,
            pos: vec![[0.0; 3]; 2],
        });
        pending.reply.finish(Ok(Reply::Rollout(RolloutSummary {
            id: 9,
            steps: 1,
            final_pos: vec![[0.0; 3]; 2],
            final_energy: -0.5,
        })));
        let out = ticket.try_poll().expect("done").expect("ok");
        assert_eq!(out.frames.len(), 1);
        assert_eq!(out.summary.steps, 1);
        // the result is delivered exactly once: polling again after the
        // sender is gone must NOT fabricate a phantom Dropped error
        drop(pending);
        assert!(ticket.try_poll().is_none());
        assert!(ticket.try_poll().is_none());
    }

    #[test]
    fn next_frame_streams_then_ends() {
        let (mut ticket, mut pending) = Ticket::<MdRollout>::make(
            1,
            Task::MdRollout { structure: structure(1), steps: 2, dt: 0.1 },
            None,
            None,
        );
        for step in 0..2 {
            pending.reply.frame(Frame {
                step,
                time: 0.1 * (step + 1) as f64,
                energy: 0.0,
                kinetic: 0.0,
                pos: vec![[0.0; 3]],
            });
        }
        pending.reply.finish(Ok(Reply::Rollout(RolloutSummary {
            id: 1,
            steps: 2,
            final_pos: vec![[0.0; 3]],
            final_energy: 0.0,
        })));
        assert_eq!(ticket.next_frame().unwrap().step, 0);
        assert_eq!(ticket.next_frame().unwrap().step, 1);
        assert!(ticket.next_frame().is_none());
        // the final reply is already buffered; wait returns immediately
        let out = ticket.wait().unwrap();
        assert_eq!(out.summary.steps, 2);
        assert!(out.frames.is_empty(), "frames were drained by next_frame");
    }

    #[test]
    fn task_validation_catches_malformed_submissions() {
        let ok = Task::EnergyForces { structure: structure(3) };
        assert!(ok.validate().is_ok());
        let empty = Task::EnergyOnly {
            structure: Structure { pos: vec![], species: vec![] },
        };
        assert!(empty.validate().is_err());
        let mismatched = Task::EnergyForces {
            structure: Structure { pos: vec![[0.0; 3]], species: vec![0, 1] },
        };
        assert!(mismatched.validate().is_err());
        let bad_dt = Task::MdRollout {
            structure: structure(2),
            steps: 5,
            dt: 0.0,
        };
        assert!(bad_dt.validate().is_err());
        let empty_batch = Task::Batch { structures: vec![] };
        assert!(empty_batch.validate().is_err());
        let oversized_batch = Task::Batch {
            structures: vec![structure(1); MAX_BATCH_STRUCTURES + 1],
        };
        assert!(oversized_batch.validate().is_err(),
                "batches above the structure cap must be rejected");
        let max_batch = Task::Batch {
            structures: vec![structure(1); MAX_BATCH_STRUCTURES],
        };
        assert!(max_batch.validate().is_ok());
        assert!(Task::Relax { structure: structure(2), max_steps: 0 }
            .validate()
            .is_err());
        // step-budget watchdogs: unbounded long tasks are refused at
        // submit time, the documented caps still pass
        assert!(Task::Relax {
            structure: structure(2),
            max_steps: MAX_RELAX_STEPS + 1,
        }
        .validate()
        .is_err());
        assert!(Task::Relax {
            structure: structure(2),
            max_steps: MAX_RELAX_STEPS,
        }
        .validate()
        .is_ok());
        assert!(Task::MdRollout {
            structure: structure(2),
            steps: MAX_ROLLOUT_STEPS + 1,
            dt: 0.1,
        }
        .validate()
        .is_err());
    }

    #[test]
    fn task_priority_orders_shedding() {
        let batch = Task::Batch { structures: vec![structure(1)] };
        let eval = Task::EnergyForces { structure: structure(1) };
        let roll =
            Task::MdRollout { structure: structure(1), steps: 1, dt: 0.1 };
        assert!(batch.priority() < eval.priority());
        assert!(eval.priority() < roll.priority());
    }

    #[test]
    fn task_shape_helpers() {
        let t = Task::Batch {
            structures: vec![structure(2), structure(7), structure(4)],
        };
        assert_eq!(t.n_atoms_max(), 7);
        assert_eq!(t.structures().len(), 3);
        assert_eq!(t.label(), "batch");
    }
}
