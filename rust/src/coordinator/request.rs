//! Request/response types for the force-field service.

use std::sync::mpsc::Sender;
use std::time::Instant;

/// A single-structure inference request.
#[derive(Clone, Debug)]
pub struct ForceRequest {
    pub id: u64,
    pub pos: Vec<[f64; 3]>,
    pub species: Vec<usize>,
}

/// The model's answer.
#[derive(Clone, Debug)]
pub struct ForceResponse {
    pub id: u64,
    pub energy: f64,
    pub forces: Vec<[f64; 3]>,
    /// queueing + execution latency in seconds
    pub latency_s: f64,
}

/// Internal envelope: request + reply channel + enqueue timestamp.
pub struct Envelope {
    pub req: ForceRequest,
    pub reply: Sender<Result<ForceResponse, String>>,
    pub enqueued: Instant,
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn envelope_reply_round_trip() {
        let (tx, rx) = channel();
        let env = Envelope {
            req: ForceRequest { id: 7, pos: vec![[0.0; 3]], species: vec![0] },
            reply: tx,
            enqueued: Instant::now(),
        };
        env.reply
            .send(Ok(ForceResponse {
                id: env.req.id,
                energy: -1.0,
                forces: vec![[0.0; 3]],
                latency_s: 0.001,
            }))
            .unwrap();
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.id, 7);
    }
}
