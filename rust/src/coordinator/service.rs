//! The typed multi-task serving engine.
//!
//! ```text
//!   Client::submit(Request<T>) ── Ticket<T>
//!        │                           ▲ wait / try_poll / next_frame
//!        ▼                           │ (reply-on-drop: never hangs)
//!   BucketedBatcher (per-atom-count shape buckets, per-bucket policy)
//!        │ next_batch()
//!        ▼
//!   worker pool ── catch_unwind ── resolve Registry endpoint ONCE
//!        │                          (hot swap is between-batches only)
//!        ├─ EnergyOnly/EnergyForces/Batch: route → pad to the BUCKET
//!        │    width → Backend::run → unpad → typed replies
//!        └─ Relax/MdRollout: long task on the worker — FIRE / BAOAB
//!             over the resolved LearnedPotential (or the backend for
//!             surrogate/XLA serving), frames streamed per step,
//!             cancellation + deadline checked every force evaluation
//! ```
//!
//! Build one with [`Service::builder`]: pick a backend
//! ([`NativeGauntBackend`] or any [`BackendSpec`]), optionally a model
//! (registered as the default endpoint, hot-swappable via
//! [`Service::promote`]), shape buckets, and a worker count.  The
//! legacy [`crate::coordinator::server::ForceFieldServer`] is a thin
//! wrapper over this builder.
//!
//! Deadlines are checked at dequeue (a request that expired in the
//! queue is failed without execution) and between every relax/rollout
//! force evaluation; batched evaluations that started before the
//! deadline run to completion.

use std::cell::RefCell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::thread::JoinHandle;
use std::time::Instant;

use super::batcher::{BatchPolicy, BucketConfig, BucketedBatcher};
use super::metrics::Metrics;
use super::registry::{Registry, DEFAULT_ENDPOINT};
use super::request::{
    EnergyOut, ForceResponse, Frame, Pending, Reply, Request, RolloutSummary,
    ServiceError, Task, TaskSpec, Ticket,
};
use super::router::Router;
use super::server::{BackendSpec, NativeGauntBackend, ServerConfig};
use crate::data::{Graph, PaddedBatch};
use crate::md::integrator::{Integrator, Thermostat};
use crate::md::potential::LearnedPotential;
use crate::md::relax::{fire_relax, FireConfig};
use crate::model::Model;
use crate::runtime::Tensor;
use crate::tp::engine::{CacheStats, PlanCache};
use crate::util::error::Result;
use crate::util::rng::Rng;

struct ServiceShared {
    backend: Arc<dyn super::server::Backend>,
    router: Router,
    queue: BucketedBatcher,
    registry: Registry,
    metrics: Metrics,
    /// artifact state tensors (XLA path), swappable via `set_state`
    state: RwLock<Arc<Vec<Tensor>>>,
    /// fallback neighbor cutoff (a resolved model's own `r_cut` wins)
    r_cut: f64,
    next_id: AtomicU64,
}

/// The serving coordinator: typed tasks, shape-bucketed batching,
/// versioned model endpoints with hot swap.
pub struct Service {
    shared: Arc<ServiceShared>,
    workers: Vec<JoinHandle<()>>,
}

impl Service {
    pub fn builder() -> ServiceBuilder {
        ServiceBuilder::new()
    }

    /// A cheap cloneable submission handle.
    pub fn client(&self) -> Client {
        Client { shared: self.shared.clone() }
    }

    pub fn metrics(&self) -> &Metrics {
        &self.shared.metrics
    }

    pub fn registry(&self) -> &Registry {
        &self.shared.registry
    }

    /// Hot-swap `model` into endpoint `name` (warming its plans first);
    /// returns the new version.  In-flight batches keep the version
    /// they resolved — a swap can never tear a batch.
    pub fn promote(&self, name: &str, model: Arc<Model>) -> u64 {
        model.warm();
        self.shared.registry.register(name, model)
    }

    /// Replace the artifact state tensors (XLA serving path).
    pub fn set_state(&self, state: Vec<Tensor>) {
        *self.shared.state.write().unwrap() = Arc::new(state);
    }

    /// Snapshot of the global plan cache — the numbers folded into
    /// [`Metrics::report`] after every batch, with per-key detail.
    pub fn plan_stats(&self) -> CacheStats {
        PlanCache::global().stats()
    }

    /// Largest structure any shape bucket accepts.
    pub fn max_atoms(&self) -> usize {
        self.shared.queue.max_atoms()
    }

    pub fn buckets(&self) -> &[BucketConfig] {
        self.shared.queue.buckets()
    }

    /// Close the queue (failing every still-queued request
    /// deterministically) and join the workers.
    pub fn shutdown(self) {
        self.shared.queue.close();
        for w in self.workers {
            let _ = w.join();
        }
    }
}

/// Cloneable, thread-safe submission handle.
#[derive(Clone)]
pub struct Client {
    shared: Arc<ServiceShared>,
}

impl Client {
    /// Submit a typed request; returns a non-blocking [`Ticket`].
    /// Rejections (validation, unknown endpoint, oversize structure,
    /// backpressure) are synchronous typed errors.
    pub fn submit<T: TaskSpec>(
        &self, req: Request<T>,
    ) -> std::result::Result<Ticket<T>, ServiceError> {
        let s = &self.shared;
        let Request { payload, deadline, model } = req;
        let task = payload.into_task();
        if let Err(msg) = task.validate() {
            s.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(ServiceError::Rejected(msg));
        }
        let n = task.n_atoms_max();
        if n > s.queue.max_atoms() {
            s.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(ServiceError::Rejected(format!(
                "structure has {n} atoms, largest shape bucket holds {} \
                 (see Service::max_atoms)",
                s.queue.max_atoms()
            )));
        }
        if let Some(name) = &model {
            if !s.registry.contains(name) {
                s.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                return Err(ServiceError::Rejected(format!(
                    "unknown model endpoint '{name}'"
                )));
            }
        }
        let id = s.next_id.fetch_add(1, Ordering::Relaxed);
        let (ticket, pending) = Ticket::<T>::make(id, task, model, deadline);
        s.metrics.requests.fetch_add(1, Ordering::Relaxed);
        match s.queue.push(pending) {
            Ok(()) => Ok(ticket),
            Err((pending, why)) => {
                s.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                // the ticket dies here; fail its channel explicitly so
                // even a caller that raced a clone of it unblocks
                pending.finish(Err(ServiceError::Rejected(why.clone())));
                Err(ServiceError::Rejected(why))
            }
        }
    }

    /// Submit and wait — the one-call form.
    pub fn call<T: TaskSpec>(
        &self, req: Request<T>,
    ) -> std::result::Result<T::Output, ServiceError> {
        self.submit(req)?.wait()
    }

    pub fn metrics(&self) -> &Metrics {
        &self.shared.metrics
    }
}

// ---------------------------------------------------------------------
// builder
// ---------------------------------------------------------------------

/// Builder for [`Service`] — the one construction path every serving
/// entry point funnels through.
pub struct ServiceBuilder {
    spec: Option<BackendSpec>,
    native: Option<NativeGauntBackend>,
    model: Option<Arc<Model>>,
    cfg: ServerConfig,
    buckets: Option<Vec<BucketConfig>>,
}

impl ServiceBuilder {
    fn new() -> ServiceBuilder {
        ServiceBuilder {
            spec: None,
            native: None,
            model: None,
            cfg: ServerConfig::default(),
            buckets: None,
        }
    }

    /// Serve an explicit [`BackendSpec`] (compiled artifacts or a
    /// custom backend).
    pub fn backend(mut self, spec: BackendSpec) -> Self {
        self.spec = Some(spec);
        self
    }

    /// Serve the native Gaunt backend.  A fixed model attached to it is
    /// moved into the registry's default endpoint (hot-swappable).
    pub fn native(mut self, backend: NativeGauntBackend) -> Self {
        self.native = Some(backend);
        self
    }

    /// Register `model` as the default endpoint (implies the native
    /// backend unless one was given).
    pub fn model(mut self, model: Arc<Model>) -> Self {
        self.model = Some(model);
        self
    }

    pub fn config(mut self, cfg: ServerConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Default flush policy (buckets added later inherit it).
    pub fn policy(mut self, policy: BatchPolicy) -> Self {
        self.cfg.policy = policy;
        self
    }

    pub fn workers(mut self, n: usize) -> Self {
        self.cfg.n_workers = n;
        self
    }

    pub fn r_cut(mut self, r_cut: f64) -> Self {
        self.cfg.r_cut = r_cut;
        self
    }

    /// Serving arithmetic precision for the native Gaunt pipeline:
    /// train f64, optionally serve `Precision::F32` (the op-conformance
    /// suite pins the f32 tolerance tier; see DESIGN.md §11).
    pub fn precision(mut self, p: crate::tp::engine::Precision) -> Self {
        self.cfg.precision = p;
        self
    }

    /// Explicit shape-bucket ladder (replaces the defaults).
    pub fn buckets(mut self, buckets: Vec<BucketConfig>) -> Self {
        self.buckets = Some(buckets);
        self
    }

    /// Append one shape bucket with the current default policy.
    pub fn bucket(mut self, max_atoms: usize, max_edges: usize) -> Self {
        let b = BucketConfig {
            max_atoms,
            max_edges,
            policy: self.cfg.policy,
        };
        self.buckets.get_or_insert_with(Vec::new).push(b);
        self
    }

    pub fn build(self) -> Result<Service> {
        let ServiceBuilder { spec, native, model, mut cfg, buckets } = self;
        // resolve the backend spec; extract a fixed native model so it
        // lives in the registry (hot-swappable) instead of the backend
        let (spec, model) = match spec {
            Some(spec) => (spec, model),
            None => {
                let mut nb = native.unwrap_or_default();
                let model = model.or_else(|| nb.model.take());
                let spec = BackendSpec::native(nb, &mut cfg);
                (spec, model)
            }
        };
        if let Some(m) = &model {
            // serving-side edge building must match the model's training
            // cutoff, or edges are silently dropped/zero-weighted
            cfg.r_cut = m.cfg.r_cut;
        }
        let buckets = if spec.fixed_shape {
            // compiled artifacts bake their padding shape in: exactly
            // one bucket of the artifact shape
            vec![BucketConfig {
                max_atoms: spec.n_atoms,
                max_edges: spec.n_edges,
                policy: cfg.policy,
            }]
        } else {
            buckets
                .or_else(|| cfg.buckets.clone())
                .unwrap_or_else(|| {
                    default_buckets(spec.n_atoms, spec.n_edges, cfg.policy)
                })
        };
        let shared = Arc::new(ServiceShared {
            backend: spec.backend,
            router: Router::new(spec.variants),
            queue: BucketedBatcher::new(buckets),
            registry: Registry::new(),
            metrics: Metrics::new(),
            state: RwLock::new(Arc::new(spec.state)),
            r_cut: cfg.r_cut,
            next_id: AtomicU64::new(1),
        });
        if let Some(m) = model {
            m.warm();
            shared.registry.register(DEFAULT_ENDPOINT, m);
        }
        let mut workers = Vec::new();
        for w in 0..cfg.n_workers.max(1) {
            let s = shared.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("svc-worker-{w}"))
                    .spawn(move || worker_loop(&s))
                    .expect("spawn worker"),
            );
        }
        Ok(Service { shared, workers })
    }
}

/// Width-halving bucket ladder up to the spec capacity, each bucket's
/// edge budget fully connected up to the spec's edge cap: capacity 32
/// with 256 edge slots gives [8/56, 16/240, 32/256].
fn default_buckets(
    max_atoms: usize, max_edges: usize, policy: BatchPolicy,
) -> Vec<BucketConfig> {
    let mut out: Vec<BucketConfig> = Vec::new();
    for w in [max_atoms / 4, max_atoms / 2, max_atoms] {
        if w == 0 || out.iter().any(|b| b.max_atoms == w) {
            continue;
        }
        let edges = (w * w.saturating_sub(1)).clamp(1, max_edges.max(1));
        out.push(BucketConfig { max_atoms: w, max_edges: edges, policy });
    }
    out
}

// ---------------------------------------------------------------------
// worker side
// ---------------------------------------------------------------------

fn worker_loop(s: &Arc<ServiceShared>) {
    while let Some((bucket_idx, batch)) = s.queue.next_batch() {
        // a panicking backend must not kill the worker — and the moved
        // batch unwinds through the reply-on-drop guards, so every
        // caller gets Err(Dropped) instead of a hang
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            process_batch(s, bucket_idx, batch);
        }));
        if outcome.is_err() {
            s.metrics.worker_panics.fetch_add(1, Ordering::Relaxed);
        }
    }
}

fn process_batch(s: &Arc<ServiceShared>, bucket_idx: usize, batch: Vec<Pending>) {
    let now = Instant::now();
    let mut evals: Vec<Pending> = Vec::new();
    let mut longs: Vec<Pending> = Vec::new();
    for p in batch {
        if p.canceled() {
            s.metrics.canceled.fetch_add(1, Ordering::Relaxed);
            p.finish(Err(ServiceError::Canceled));
        } else if p.expired(now) {
            s.metrics.expired.fetch_add(1, Ordering::Relaxed);
            p.finish(Err(ServiceError::DeadlineExceeded));
        } else if matches!(p.task, Task::Relax { .. } | Task::MdRollout { .. })
        {
            longs.push(p);
        } else {
            evals.push(p);
        }
    }
    if !evals.is_empty() {
        // group by endpoint so one padded batch never mixes two models
        // (the torn-batch guarantee), preserving submission order
        let mut groups: Vec<(Option<String>, Vec<Pending>)> = Vec::new();
        for p in evals {
            match groups.iter_mut().find(|(name, _)| *name == p.model) {
                Some((_, v)) => v.push(p),
                None => groups.push((p.model.clone(), vec![p])),
            }
        }
        for (name, group) in groups {
            run_eval_group(s, bucket_idx, name.as_deref(), group);
        }
    }
    for p in longs {
        run_long(s, bucket_idx, p);
    }
}

/// Evaluate a group of batchable tasks (same endpoint) as padded
/// chunks through the backend.
fn run_eval_group(
    s: &Arc<ServiceShared>, bucket_idx: usize, name: Option<&str>,
    group: Vec<Pending>,
) {
    let bucket = s.queue.bucket(bucket_idx);
    let mv = s.registry.resolve(name);
    if name.is_some() && mv.is_none() {
        // the endpoint vanished between submit and execution
        let msg = format!("unknown model endpoint '{}'", name.unwrap());
        for p in group {
            s.metrics.failed.fetch_add(1, Ordering::Relaxed);
            p.finish(Err(ServiceError::Rejected(msg.clone())));
        }
        return;
    }
    let model = mv.as_ref().map(|v| v.model.clone());
    let r_cut = model.as_ref().map(|m| m.cfg.r_cut).unwrap_or(s.r_cut);
    // flatten every task's structures into batch rows
    let mut graphs: Vec<Graph> = Vec::new();
    let mut spans: Vec<(usize, usize)> = Vec::new();
    for p in &group {
        let start = graphs.len();
        for st in p.task.structures() {
            graphs.push(Graph {
                pos: st.pos.clone(),
                species: st.species.clone(),
                energy: 0.0,
                forces: vec![[0.0; 3]; st.pos.len()],
            });
        }
        spans.push((start, graphs.len() - start));
    }
    // route into variant-sized chunks and execute; the model Arc
    // resolved above is used for EVERY chunk of this group
    let state = s.state.read().unwrap().clone();
    type RowResult = std::result::Result<(f64, Vec<[f64; 3]>), String>;
    let mut row_results: Vec<RowResult> = Vec::with_capacity(graphs.len());
    let plan = s.router.plan(graphs.len());
    let mut offset = 0usize;
    for (variant, k) in plan {
        let chunk = &graphs[offset..offset + k];
        offset += k;
        let t_exec = Instant::now();
        let pb = PaddedBatch::from_graphs(
            chunk, variant.batch, bucket.max_atoms, bucket.max_edges, r_cut,
        );
        let res =
            s.backend.run(variant, &pb, state.as_ref(), model.as_ref());
        s.metrics
            .exec_latency
            .record_ns(t_exec.elapsed().as_nanos() as u64);
        observe_chunk(s, &pb, variant.batch, k);
        match res {
            Ok((energy, forces)) => {
                for (g_idx, g) in chunk.iter().enumerate() {
                    let na = g.pos.len();
                    let mut f = Vec::with_capacity(na);
                    for a in 0..na {
                        let base = (g_idx * bucket.max_atoms + a) * 3;
                        f.push([
                            forces[base] as f64,
                            forces[base + 1] as f64,
                            forces[base + 2] as f64,
                        ]);
                    }
                    row_results.push(Ok((energy[g_idx] as f64, f)));
                }
            }
            Err(e) => {
                let msg = format!("{e}");
                for _ in 0..k {
                    row_results.push(Err(msg.clone()));
                }
            }
        }
    }
    // assemble the typed replies
    for (p, (start, len)) in group.into_iter().zip(spans) {
        let rows = &row_results[start..start + len];
        if let Some(e) = rows.iter().find_map(|r| r.as_ref().err()) {
            s.metrics.failed.fetch_add(1, Ordering::Relaxed);
            p.finish(Err(ServiceError::Exec(e.clone())));
            continue;
        }
        let lat = p.enqueued.elapsed();
        s.metrics.latency.record_ns(lat.as_nanos() as u64);
        let latency_s = lat.as_secs_f64();
        let id = p.id;
        let reply = match &p.task {
            Task::EnergyOnly { .. } => {
                let (energy, _) = rows[0].as_ref().unwrap();
                Reply::Energy(EnergyOut { id, energy: *energy, latency_s })
            }
            Task::EnergyForces { .. } => {
                let (energy, forces) = rows[0].as_ref().unwrap();
                Reply::EnergyForces(ForceResponse {
                    id,
                    energy: *energy,
                    forces: forces.clone(),
                    latency_s,
                })
            }
            Task::Batch { .. } => Reply::Batch(
                rows.iter()
                    .map(|r| {
                        let (energy, forces) = r.as_ref().unwrap();
                        ForceResponse {
                            id,
                            energy: *energy,
                            forces: forces.clone(),
                            latency_s,
                        }
                    })
                    .collect(),
            ),
            Task::Relax { .. } | Task::MdRollout { .. } => {
                unreachable!("long tasks are never batch-evaluated")
            }
        };
        s.metrics.responses.fetch_add(1, Ordering::Relaxed);
        p.finish(Ok(reply));
    }
}

/// Fold one executed chunk into the serving metrics (batch counters,
/// padding accounting, plan-cache gauges).
fn observe_chunk(
    s: &ServiceShared, pb: &PaddedBatch, row_slots: usize, occupied: usize,
) {
    s.metrics.batches.fetch_add(1, Ordering::Relaxed);
    s.metrics
        .batched_requests
        .fetch_add(occupied as u64, Ordering::Relaxed);
    s.metrics
        .padding_waste
        .fetch_add((row_slots - occupied) as u64, Ordering::Relaxed);
    let true_atoms: usize = pb.true_atoms.iter().sum();
    s.metrics.observe_padding(
        row_slots as u64,
        pb.n_atoms as u64,
        true_atoms as u64,
    );
    let cache = PlanCache::global();
    s.metrics.observe_plans(
        cache.builds() as u64,
        cache.hits() as u64,
        cache.len() as u64,
    );
}

/// Evaluate one structure through the backend (the relax/rollout force
/// provider when no learned model is resolved — surrogate or XLA).
fn eval_single(
    s: &ServiceShared, bucket: BucketConfig, state: &Arc<Vec<Tensor>>,
    pos: &[[f64; 3]], species: &[usize],
) -> Result<(f64, Vec<[f64; 3]>)> {
    let g = Graph {
        pos: pos.to_vec(),
        species: species.to_vec(),
        energy: 0.0,
        forces: vec![[0.0; 3]; pos.len()],
    };
    let variant = s.router.pick(1);
    let t_exec = Instant::now();
    let pb = PaddedBatch::from_graphs(
        std::slice::from_ref(&g), variant.batch, bucket.max_atoms,
        bucket.max_edges, s.r_cut,
    );
    let (energy, forces) =
        s.backend.run(variant, &pb, state.as_ref(), None)?;
    s.metrics
        .exec_latency
        .record_ns(t_exec.elapsed().as_nanos() as u64);
    observe_chunk(s, &pb, variant.batch, 1);
    let na = pos.len();
    let mut f = Vec::with_capacity(na);
    for a in 0..na {
        let base = a * 3;
        f.push([
            forces[base] as f64,
            forces[base + 1] as f64,
            forces[base + 2] as f64,
        ]);
    }
    Ok((energy[0] as f64, f))
}

/// Run a relax or rollout task on this worker.  Force evaluations go
/// through the resolved model's [`LearnedPotential`] (f64, zero-copy
/// scratch reuse along the trajectory) or, without a model, through the
/// backend one padded structure at a time.  Cancellation, deadline, and
/// backend errors surface as typed errors; rollout frames stream as the
/// integration advances.
fn run_long(s: &Arc<ServiceShared>, bucket_idx: usize, p: Pending) {
    let Pending { id, task, model: name, enqueued, deadline, cancel, reply } =
        p;
    let mut reply = reply;
    let bucket = s.queue.bucket(bucket_idx);
    let mv = s.registry.resolve(name.as_deref());
    if name.is_some() && mv.is_none() {
        s.metrics.failed.fetch_add(1, Ordering::Relaxed);
        reply.finish(Err(ServiceError::Rejected(format!(
            "unknown model endpoint '{}'",
            name.unwrap()
        ))));
        return;
    }
    let model = mv.as_ref().map(|v| v.model.clone());
    enum Long {
        Relax { max_steps: usize },
        Roll { steps: usize, dt: f64 },
    }
    let (pos0, species, kind) = match task {
        Task::Relax { structure, max_steps } => {
            (structure.pos, structure.species, Long::Relax { max_steps })
        }
        Task::MdRollout { structure, steps, dt } => {
            (structure.pos, structure.species, Long::Roll { steps, dt })
        }
        _ => unreachable!("run_long only sees Relax/MdRollout"),
    };
    if let Some(m) = &model {
        if species.len() > m.cfg.max_atoms {
            s.metrics.failed.fetch_add(1, Ordering::Relaxed);
            reply.finish(Err(ServiceError::Exec(format!(
                "structure has {} atoms, model capacity is {}",
                species.len(),
                m.cfg.max_atoms
            ))));
            return;
        }
    }
    let mut learned =
        model.as_ref().map(|m| LearnedPotential::new(m.clone(), species.clone()));
    let state = s.state.read().unwrap().clone();
    // first typed error wins; once set, the provider returns zero forces
    // so FIRE/BAOAB wind down in O(1) steps instead of integrating noise
    let err: RefCell<Option<ServiceError>> = RefCell::new(None);
    let cancel_flag = cancel.clone();
    let species_for_provider = species.clone();
    let mut provider = |pos: &[[f64; 3]]| -> (f64, Vec<[f64; 3]>) {
        let zeros = (0.0, vec![[0.0f64; 3]; pos.len()]);
        if err.borrow().is_some() {
            return zeros;
        }
        if cancel_flag.load(Ordering::Relaxed) {
            *err.borrow_mut() = Some(ServiceError::Canceled);
            return zeros;
        }
        if deadline.map_or(false, |d| Instant::now() >= d) {
            *err.borrow_mut() = Some(ServiceError::DeadlineExceeded);
            return zeros;
        }
        match &mut learned {
            Some(lp) => lp.compute(pos),
            None => match eval_single(
                s, bucket, &state, pos, &species_for_provider,
            ) {
                Ok(r) => r,
                Err(e) => {
                    *err.borrow_mut() =
                        Some(ServiceError::Exec(format!("{e}")));
                    zeros
                }
            },
        }
    };
    match kind {
        Long::Relax { max_steps } => {
            let res = fire_relax(
                &mut provider,
                &pos0,
                FireConfig { max_steps, ..Default::default() },
            );
            s.metrics.relaxes.fetch_add(1, Ordering::Relaxed);
            match err.into_inner() {
                Some(e) => {
                    count_failure(s, &e);
                    reply.finish(Err(e));
                }
                None => {
                    let lat = enqueued.elapsed();
                    s.metrics.latency.record_ns(lat.as_nanos() as u64);
                    s.metrics.responses.fetch_add(1, Ordering::Relaxed);
                    reply.finish(Ok(Reply::Relaxed(res)));
                }
            }
        }
        Long::Roll { steps, dt } => {
            // Thermostat::None consumes no randomness: the rollout is
            // deterministic and exactly reproducible client-side
            let mut rng = Rng::new(id);
            let mut md = Integrator::new_with(
                pos0, species.clone(), &mut provider, dt, Thermostat::None,
            );
            let mut streamed = 0usize;
            md.rollout_with(&mut provider, &mut rng, steps, |step, md| {
                if err.borrow().is_some() {
                    return false;
                }
                reply.frame(Frame {
                    step,
                    time: (step + 1) as f64 * dt,
                    energy: md.potential_energy,
                    kinetic: md.kinetic_energy(),
                    pos: md.pos.clone(),
                });
                streamed += 1;
                s.metrics.frames.fetch_add(1, Ordering::Relaxed);
                true
            });
            s.metrics.rollouts.fetch_add(1, Ordering::Relaxed);
            match err.into_inner() {
                Some(e) => {
                    count_failure(s, &e);
                    reply.finish(Err(e));
                }
                None => {
                    let lat = enqueued.elapsed();
                    s.metrics.latency.record_ns(lat.as_nanos() as u64);
                    s.metrics.responses.fetch_add(1, Ordering::Relaxed);
                    reply.finish(Ok(Reply::Rollout(RolloutSummary {
                        id,
                        steps: streamed,
                        final_pos: md.pos.clone(),
                        final_energy: md.total_energy(),
                    })));
                }
            }
        }
    }
}

fn count_failure(s: &ServiceShared, e: &ServiceError) {
    match e {
        ServiceError::Canceled => {
            s.metrics.canceled.fetch_add(1, Ordering::Relaxed);
        }
        ServiceError::DeadlineExceeded => {
            s.metrics.expired.fetch_add(1, Ordering::Relaxed);
        }
        _ => {
            s.metrics.failed.fetch_add(1, Ordering::Relaxed);
        }
    }
}
