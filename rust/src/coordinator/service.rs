//! The typed multi-task serving engine.
//!
//! ```text
//!   Client::submit(Request<T>) ── Ticket<T>
//!        │                           ▲ wait / try_poll / next_frame
//!        ▼                           │ (reply-on-drop: never hangs)
//!   BucketedBatcher (per-atom-count shape buckets, per-bucket policy)
//!        │ next_batch()
//!        ▼
//!   worker pool ── catch_unwind ── resolve Registry endpoint ONCE
//!        │                          (hot swap is between-batches only)
//!        ├─ EnergyOnly/EnergyForces/Batch: route → pad to the BUCKET
//!        │    width → Backend::run → unpad → typed replies
//!        └─ Relax/MdRollout: long task on the worker — FIRE / BAOAB
//!             over the resolved LearnedPotential (or the backend for
//!             surrogate/XLA serving), frames streamed per step,
//!             cancellation + deadline checked every force evaluation
//! ```
//!
//! Build one with [`Service::builder`]: pick a backend
//! ([`NativeGauntBackend`] or any [`BackendSpec`]), optionally a model
//! (registered as the default endpoint, hot-swappable via
//! [`Service::promote`]), shape buckets, and a worker count.  The
//! legacy [`crate::coordinator::server::ForceFieldServer`] is a thin
//! wrapper over this builder.
//!
//! Deadlines are checked at dequeue (a request that expired in the
//! queue is failed without execution) and between every relax/rollout
//! force evaluation; batched evaluations that started before the
//! deadline run to completion.

use std::cell::{Cell, RefCell};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::batcher::{BatchPolicy, BucketConfig, BucketedBatcher, PushError};
use super::metrics::Metrics;
use super::registry::{Registry, DEFAULT_ENDPOINT};
use super::request::{
    EnergyOut, ExecFault, ForceResponse, Frame, Pending, RawTicket, Reply,
    Request, RolloutSummary, ServiceError, Task, TaskSpec, Ticket,
};
use super::router::Router;
use super::server::{BackendSpec, NativeGauntBackend, ServerConfig};
use crate::data::{Graph, PaddedBatch};
use crate::md::integrator::{Integrator, Thermostat};
use crate::md::potential::LearnedPotential;
use crate::md::relax::{fire_relax, FireConfig};
use crate::model::Model;
use crate::runtime::Tensor;
use crate::tp::engine::{CacheStats, PlanCache};
use crate::util::error::Result;
use crate::util::rng::Rng;
use crate::util::{failpoint, sync};

// ---------------------------------------------------------------------
// resilience configuration
// ---------------------------------------------------------------------

/// Supervisor tuning: how dead/hung workers are detected and respawned.
#[derive(Clone, Copy, Debug)]
pub struct SupervisorConfig {
    /// run the supervisor thread at all
    pub enabled: bool,
    /// supervisor scan period (also bounds shutdown-join latency)
    pub heartbeat_interval: Duration,
    /// a busy worker whose heartbeat is staler than this is declared
    /// hung, detached, and replaced
    pub hang_timeout: Duration,
    /// lifetime respawn budget per worker slot — a crash loop must
    /// converge to a smaller pool, not spin forever
    pub max_restarts: u32,
    /// first respawn delay; doubles per restart of the slot
    pub backoff_base: Duration,
    /// respawn delay ceiling
    pub backoff_cap: Duration,
}

impl Default for SupervisorConfig {
    fn default() -> Self {
        SupervisorConfig {
            enabled: true,
            heartbeat_interval: Duration::from_millis(20),
            hang_timeout: Duration::from_secs(2),
            max_restarts: 8,
            backoff_base: Duration::from_millis(50),
            backoff_cap: Duration::from_secs(5),
        }
    }
}

/// Admission-control watermarks, as fractions of total queue capacity
/// (the sum of every bucket's `max_queue`).
#[derive(Clone, Copy, Debug)]
pub struct AdmissionConfig {
    /// at or above this queue-depth fraction, shed priority-0 work
    /// (Batch)
    pub low_watermark: f64,
    /// at or above this fraction, also shed priority-1 work
    /// (EnergyOnly/EnergyForces); only streaming long tasks get through
    pub high_watermark: f64,
    /// the `retry_after` hint attached to `ServiceError::Overloaded`
    pub retry_after: Duration,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            low_watermark: 0.5,
            high_watermark: 0.75,
            retry_after: Duration::from_millis(20),
        }
    }
}

/// The admission state machine's observable position.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HealthState {
    /// below the low watermark: everything is admitted
    Healthy,
    /// between watermarks (or above): lower-priority classes are shed
    Shedding,
    /// `Service::drain` was called: every new submission is refused,
    /// queued work keeps executing
    Draining,
}

/// Client-side retry tuning for [`Client::submit_with_retry`].
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// total submit attempts (first try included)
    pub max_attempts: u32,
    /// first backoff; doubles per attempt (full jitter on top)
    pub base: Duration,
    /// backoff ceiling
    pub cap: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 4,
            base: Duration::from_millis(5),
            cap: Duration::from_millis(500),
        }
    }
}

// ---------------------------------------------------------------------
// worker heartbeats
// ---------------------------------------------------------------------

/// Shared heartbeat cell between one worker thread and the supervisor.
/// `beat_ms` is milliseconds since `ServiceShared.start` — relative so
/// it fits an atomic without wall-clock syscalls on the hot path.
struct WorkerBeat {
    busy: AtomicBool,
    beat_ms: AtomicU64,
}

impl WorkerBeat {
    fn new(now_ms: u64) -> WorkerBeat {
        WorkerBeat {
            busy: AtomicBool::new(false),
            beat_ms: AtomicU64::new(now_ms),
        }
    }

    fn touch(&self, s: &ServiceShared) {
        self.beat_ms
            .store(s.start.elapsed().as_millis() as u64, Ordering::Relaxed);
    }
}

/// One supervised worker position: its heartbeat, its live thread (if
/// any), and its restart bookkeeping.
struct WorkerSlot {
    beat: Arc<WorkerBeat>,
    handle: Option<JoinHandle<()>>,
    restarts: u32,
    /// ms-since-start timestamp before which this slot must not be
    /// respawned (exponential backoff)
    respawn_at: Option<u64>,
}

struct ServiceShared {
    backend: Arc<dyn super::server::Backend>,
    router: Router,
    queue: BucketedBatcher,
    registry: Registry,
    metrics: Metrics,
    /// artifact state tensors (XLA path), swappable via `set_state`
    state: RwLock<Arc<Vec<Tensor>>>,
    /// fallback neighbor cutoff (a resolved model's own `r_cut` wins)
    r_cut: f64,
    next_id: AtomicU64,
    /// epoch for heartbeat timestamps
    start: Instant,
    /// total queue capacity (admission watermark denominator)
    capacity: usize,
    /// `Service::drain` was called: refuse all new submissions
    draining: AtomicBool,
    /// shutdown began: the supervisor must stop respawning
    shutdown: AtomicBool,
    slots: Mutex<Vec<WorkerSlot>>,
    supervisor: SupervisorConfig,
    admission: AdmissionConfig,
}

/// The serving coordinator: typed tasks, shape-bucketed batching,
/// versioned model endpoints with hot swap, and a supervisor that
/// respawns dead/hung workers.
pub struct Service {
    shared: Arc<ServiceShared>,
    supervisor: Option<JoinHandle<()>>,
}

impl Service {
    pub fn builder() -> ServiceBuilder {
        ServiceBuilder::new()
    }

    /// A cheap cloneable submission handle.
    pub fn client(&self) -> Client {
        Client { shared: self.shared.clone() }
    }

    pub fn metrics(&self) -> &Metrics {
        &self.shared.metrics
    }

    pub fn registry(&self) -> &Registry {
        &self.shared.registry
    }

    /// Hot-swap `model` into endpoint `name` (warming its plans first);
    /// returns the new version.  In-flight batches keep the version
    /// they resolved — a swap can never tear a batch.  A snapshot with
    /// non-finite parameters is refused (`Err`) and the old version
    /// keeps serving.
    pub fn promote(&self, name: &str, model: Arc<Model>) -> Result<u64> {
        model.warm();
        self.shared.registry.register(name, model)
    }

    /// Replace the artifact state tensors (XLA serving path).
    pub fn set_state(&self, state: Vec<Tensor>) {
        *sync::write(&self.shared.state) = Arc::new(state);
    }

    /// Stop admitting new work (every submission is rejected with a
    /// "draining" message) while queued and in-flight tasks run to
    /// completion.  Irreversible for this service instance.
    pub fn drain(&self) {
        self.shared.draining.store(true, Ordering::Relaxed);
    }

    /// Where the admission state machine currently sits.
    pub fn health(&self) -> HealthState {
        health_of(&self.shared)
    }

    /// Snapshot of the global plan cache — the numbers folded into
    /// [`Metrics::report`] after every batch, with per-key detail.
    pub fn plan_stats(&self) -> CacheStats {
        PlanCache::global().stats()
    }

    /// Largest structure any shape bucket accepts.
    pub fn max_atoms(&self) -> usize {
        self.shared.queue.max_atoms()
    }

    pub fn buckets(&self) -> &[BucketConfig] {
        self.shared.queue.buckets()
    }

    /// Close the queue (failing every still-queued request
    /// deterministically), stop the supervisor, and join the workers.
    pub fn shutdown(self) {
        self.shared.shutdown.store(true, Ordering::Relaxed);
        self.shared.queue.close();
        if let Some(h) = self.supervisor {
            let _ = h.join();
        }
        let handles: Vec<JoinHandle<()>> = {
            let mut slots = sync::lock(&self.shared.slots);
            slots.iter_mut().filter_map(|sl| sl.handle.take()).collect()
        };
        for h in handles {
            let _ = h.join();
        }
        // workers detached after a hang keep running until the closed
        // queue hands them None; they hold their own Arc<ServiceShared>
        // and exit on their own, so they are not joined here
    }
}

fn health_of(s: &ServiceShared) -> HealthState {
    if s.draining.load(Ordering::Relaxed) {
        return HealthState::Draining;
    }
    if s.capacity > 0 {
        let frac = s.queue.len() as f64 / s.capacity as f64;
        if frac >= s.admission.low_watermark {
            return HealthState::Shedding;
        }
    }
    HealthState::Healthy
}

/// Cloneable, thread-safe submission handle.
#[derive(Clone)]
pub struct Client {
    shared: Arc<ServiceShared>,
}

impl Client {
    /// Submit a typed request; returns a non-blocking [`Ticket`].
    /// Rejections (validation, unknown endpoint, oversize structure,
    /// backpressure) are synchronous typed errors.
    pub fn submit<T: TaskSpec>(
        &self, req: Request<T>,
    ) -> std::result::Result<Ticket<T>, ServiceError> {
        let Request { payload, deadline, model } = req;
        let raw = self.submit_task(payload.into_task(), deadline, model)?;
        Ok(Ticket::from_raw(raw))
    }

    /// Untyped submission — the wire path.  `net::replica` decodes a
    /// [`Task`] off a socket and admits it here without knowing its
    /// output type at compile time; the returned [`RawTicket`] carries
    /// the reply channel (pumped back over the wire) and the cancel
    /// flag (set by a wire `cancel` or connection teardown).  Runs the
    /// exact same validation/admission pipeline as [`Client::submit`]:
    /// the two entry points can never drift.
    pub fn submit_task(
        &self, task: Task, deadline: Option<Duration>, model: Option<String>,
    ) -> std::result::Result<RawTicket, ServiceError> {
        let s = &self.shared;
        if let Err(msg) = task.validate() {
            s.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(ServiceError::Rejected(msg));
        }
        let n = task.n_atoms_max();
        if n > s.queue.max_atoms() {
            s.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(ServiceError::Rejected(format!(
                "structure has {n} atoms, largest shape bucket holds {} \
                 (see Service::max_atoms)",
                s.queue.max_atoms()
            )));
        }
        if let Some(name) = &model {
            if !s.registry.contains(name) {
                s.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                return Err(ServiceError::Rejected(format!(
                    "unknown model endpoint '{name}'"
                )));
            }
        }
        // admission control: draining refuses everything; between the
        // watermarks the lowest priority class is shed first, above the
        // high watermark everything but streaming long tasks is shed.
        // Every shed ALSO counts in `rejected` so `requests` (counted
        // only for admitted submissions) keeps reconciling.
        if s.draining.load(Ordering::Relaxed) {
            s.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(ServiceError::Rejected(
                "service is draining; no new work is admitted".to_string(),
            ));
        }
        if s.capacity > 0 {
            let frac = s.queue.len() as f64 / s.capacity as f64;
            let adm = &s.admission;
            let shed = (frac >= adm.high_watermark && task.priority() <= 1)
                || (frac >= adm.low_watermark && task.priority() == 0);
            if shed {
                s.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                s.metrics.shed.fetch_add(1, Ordering::Relaxed);
                return Err(ServiceError::Overloaded {
                    retry_after: adm.retry_after,
                });
            }
        }
        let id = s.next_id.fetch_add(1, Ordering::Relaxed);
        let (ticket, pending) = RawTicket::make(id, task, model, deadline);
        s.metrics.requests.fetch_add(1, Ordering::Relaxed);
        match s.queue.push(pending) {
            Ok(()) => Ok(ticket),
            Err((pending, why)) => {
                s.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                let e = match why {
                    PushError::NoFit(m) => ServiceError::Rejected(m),
                    PushError::Full { .. } => {
                        s.metrics.shed.fetch_add(1, Ordering::Relaxed);
                        ServiceError::Overloaded {
                            retry_after: s.admission.retry_after,
                        }
                    }
                    PushError::Closed => ServiceError::Shutdown,
                };
                // the ticket dies here; fail its channel explicitly so
                // even a caller that raced a clone of it unblocks
                pending.finish(Err(e.clone()));
                Err(e)
            }
        }
    }

    /// [`Client::submit`] with jittered-exponential-backoff retries on
    /// [`ServiceError::Overloaded`].  Retries only idempotent specs
    /// (`T::IDEMPOTENT`; an `MdRollout` retry could duplicate streamed
    /// frames) and is deadline-aware: it gives up with
    /// [`ServiceError::DeadlineExceeded`] rather than sleep past the
    /// request's own deadline budget.  All other errors pass through
    /// unretried.
    pub fn submit_with_retry<T: TaskSpec + Clone>(
        &self, req: Request<T>, policy: RetryPolicy,
    ) -> std::result::Result<Ticket<T>, ServiceError> {
        let started = Instant::now();
        let mut rng = Rng::new(
            self.shared.next_id.load(Ordering::Relaxed)
                ^ 0x9e37_79b9_7f4a_7c15,
        );
        let mut attempt = 0u32;
        loop {
            match self.submit(req.clone()) {
                Err(ServiceError::Overloaded { retry_after })
                    if T::IDEMPOTENT =>
                {
                    attempt += 1;
                    if attempt >= policy.max_attempts.max(1) {
                        return Err(ServiceError::Overloaded { retry_after });
                    }
                    // exponential envelope, floored at the server's
                    // hint, with full jitter so synchronized clients
                    // don't re-stampede in lockstep
                    let envelope = (policy.base.as_secs_f64()
                        * 2f64.powi(attempt as i32 - 1))
                    .min(policy.cap.as_secs_f64())
                    .max(retry_after.as_secs_f64());
                    let backoff = Duration::from_secs_f64(
                        rng.uniform(envelope * 0.5, envelope),
                    );
                    if let Some(d) = req.deadline {
                        if started.elapsed() + backoff >= d {
                            return Err(ServiceError::DeadlineExceeded);
                        }
                    }
                    std::thread::sleep(backoff);
                }
                other => return other,
            }
        }
    }

    /// Submit and wait — the one-call form.
    pub fn call<T: TaskSpec>(
        &self, req: Request<T>,
    ) -> std::result::Result<T::Output, ServiceError> {
        self.submit(req)?.wait()
    }

    /// Where the admission state machine currently sits.
    pub fn health(&self) -> HealthState {
        health_of(&self.shared)
    }

    pub fn metrics(&self) -> &Metrics {
        &self.shared.metrics
    }

    /// Requests currently queued (the admission watermark numerator) —
    /// what a replica reports in its wire `pong`.
    pub fn queue_depth(&self) -> usize {
        self.shared.queue.len()
    }

    /// Total queue capacity (the watermark denominator).
    pub fn capacity(&self) -> usize {
        self.shared.capacity
    }

    /// Largest structure any shape bucket accepts (mirrors
    /// [`Service::max_atoms`] on the cheap handle, for the wire
    /// handshake).
    pub fn max_atoms(&self) -> usize {
        self.shared.queue.max_atoms()
    }

    /// The bucket atom-width ladder, smallest first — what the wire
    /// `hello_ack` advertises so a front door can shard by shape.
    pub fn bucket_widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self
            .shared
            .queue
            .buckets()
            .iter()
            .map(|b| b.max_atoms)
            .collect();
        w.sort_unstable();
        w
    }

    /// Stop admitting new work on the whole service (the handle-level
    /// mirror of [`Service::drain`], so a wire `drain` message can
    /// trigger it from a connection thread that only holds a `Client`).
    pub fn drain(&self) {
        self.shared.draining.store(true, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------
// builder
// ---------------------------------------------------------------------

/// Builder for [`Service`] — the one construction path every serving
/// entry point funnels through.
pub struct ServiceBuilder {
    spec: Option<BackendSpec>,
    native: Option<NativeGauntBackend>,
    model: Option<Arc<Model>>,
    cfg: ServerConfig,
    buckets: Option<Vec<BucketConfig>>,
}

impl ServiceBuilder {
    fn new() -> ServiceBuilder {
        ServiceBuilder {
            spec: None,
            native: None,
            model: None,
            cfg: ServerConfig::default(),
            buckets: None,
        }
    }

    /// Serve an explicit [`BackendSpec`] (compiled artifacts or a
    /// custom backend).
    pub fn backend(mut self, spec: BackendSpec) -> Self {
        self.spec = Some(spec);
        self
    }

    /// Serve the native Gaunt backend.  A fixed model attached to it is
    /// moved into the registry's default endpoint (hot-swappable).
    pub fn native(mut self, backend: NativeGauntBackend) -> Self {
        self.native = Some(backend);
        self
    }

    /// Register `model` as the default endpoint (implies the native
    /// backend unless one was given).
    pub fn model(mut self, model: Arc<Model>) -> Self {
        self.model = Some(model);
        self
    }

    pub fn config(mut self, cfg: ServerConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Default flush policy (buckets added later inherit it).
    pub fn policy(mut self, policy: BatchPolicy) -> Self {
        self.cfg.policy = policy;
        self
    }

    pub fn workers(mut self, n: usize) -> Self {
        self.cfg.n_workers = n;
        self
    }

    pub fn r_cut(mut self, r_cut: f64) -> Self {
        self.cfg.r_cut = r_cut;
        self
    }

    /// Serving arithmetic precision for the native Gaunt pipeline:
    /// train f64, optionally serve `Precision::F32` (the op-conformance
    /// suite pins the f32 tolerance tier; see DESIGN.md §11).
    pub fn precision(mut self, p: crate::tp::engine::Precision) -> Self {
        self.cfg.precision = p;
        self
    }

    /// Explicit shape-bucket ladder (replaces the defaults).
    pub fn buckets(mut self, buckets: Vec<BucketConfig>) -> Self {
        self.buckets = Some(buckets);
        self
    }

    /// Append one shape bucket with the current default policy.
    pub fn bucket(mut self, max_atoms: usize, max_edges: usize) -> Self {
        let b = BucketConfig {
            max_atoms,
            max_edges,
            policy: self.cfg.policy,
        };
        self.buckets.get_or_insert_with(Vec::new).push(b);
        self
    }

    pub fn build(self) -> Result<Service> {
        let ServiceBuilder { spec, native, model, mut cfg, buckets } = self;
        // resolve the backend spec; extract a fixed native model so it
        // lives in the registry (hot-swappable) instead of the backend
        let (spec, model) = match spec {
            Some(spec) => (spec, model),
            None => {
                let mut nb = native.unwrap_or_default();
                let model = model.or_else(|| nb.model.take());
                let spec = BackendSpec::native(nb, &mut cfg);
                (spec, model)
            }
        };
        if let Some(m) = &model {
            // serving-side edge building must match the model's training
            // cutoff, or edges are silently dropped/zero-weighted
            cfg.r_cut = m.cfg.r_cut;
        }
        let buckets = if spec.fixed_shape {
            // compiled artifacts bake their padding shape in: exactly
            // one bucket of the artifact shape
            vec![BucketConfig {
                max_atoms: spec.n_atoms,
                max_edges: spec.n_edges,
                policy: cfg.policy,
            }]
        } else {
            buckets
                .or_else(|| cfg.buckets.clone())
                .unwrap_or_else(|| {
                    default_buckets(spec.n_atoms, spec.n_edges, cfg.policy)
                })
        };
        let queue = BucketedBatcher::new(buckets);
        let capacity = queue.capacity();
        let shared = Arc::new(ServiceShared {
            backend: spec.backend,
            router: Router::new(spec.variants),
            queue,
            registry: Registry::new(),
            metrics: Metrics::new(),
            state: RwLock::new(Arc::new(spec.state)),
            r_cut: cfg.r_cut,
            next_id: AtomicU64::new(1),
            start: Instant::now(),
            capacity,
            draining: AtomicBool::new(false),
            shutdown: AtomicBool::new(false),
            slots: Mutex::new(Vec::new()),
            supervisor: cfg.supervisor,
            admission: cfg.admission,
        });
        if let Some(m) = model {
            m.warm();
            shared.registry.register(DEFAULT_ENDPOINT, m)?;
        }
        {
            let mut slots = sync::lock(&shared.slots);
            for w in 0..cfg.n_workers.max(1) {
                slots.push(spawn_worker(&shared, w));
            }
        }
        let supervisor = if cfg.supervisor.enabled {
            let s = shared.clone();
            Some(
                std::thread::Builder::new()
                    .name("svc-supervisor".to_string())
                    .spawn(move || supervisor_loop(&s))
                    .expect("spawn supervisor"),
            )
        } else {
            None
        };
        Ok(Service { shared, supervisor })
    }
}

/// Spawn one worker thread into a fresh [`WorkerSlot`].
fn spawn_worker(shared: &Arc<ServiceShared>, idx: usize) -> WorkerSlot {
    let now_ms = shared.start.elapsed().as_millis() as u64;
    let beat = Arc::new(WorkerBeat::new(now_ms));
    let s = shared.clone();
    let b = beat.clone();
    let handle = std::thread::Builder::new()
        .name(format!("svc-worker-{idx}"))
        .spawn(move || worker_loop(&s, &b))
        .expect("spawn worker");
    WorkerSlot { beat, handle: Some(handle), restarts: 0, respawn_at: None }
}

/// Supervisor: scan worker slots every `heartbeat_interval`, reap dead
/// threads, detach hung ones, and respawn with exponential backoff up
/// to `max_restarts` per slot.
fn supervisor_loop(s: &Arc<ServiceShared>) {
    let cfg = s.supervisor;
    let hang_ms = cfg.hang_timeout.as_millis() as u64;
    loop {
        std::thread::sleep(cfg.heartbeat_interval);
        if s.shutdown.load(Ordering::Relaxed) {
            return;
        }
        let now_ms = s.start.elapsed().as_millis() as u64;
        let mut respawn: Vec<usize> = Vec::new();
        {
            let mut slots = sync::lock(&s.slots);
            for (i, slot) in slots.iter_mut().enumerate() {
                if let Some(h) = &slot.handle {
                    if h.is_finished() {
                        // the worker died (a panic escaped the batch
                        // catch — e.g. inside the queue itself); reap
                        // and schedule a replacement
                        if let Some(h) = slot.handle.take() {
                            let _ = h.join();
                        }
                        schedule_respawn(slot, now_ms, &cfg);
                    } else if slot.beat.busy.load(Ordering::Relaxed)
                        && now_ms
                            .saturating_sub(
                                slot.beat.beat_ms.load(Ordering::Relaxed),
                            )
                            > hang_ms
                    {
                        // hung: the heartbeat went stale mid-batch.
                        // Detach the thread (it keeps exclusive
                        // ownership of its batch, so replies stay
                        // exactly-once; it exits when the queue closes)
                        // and backfill the slot.
                        s.metrics
                            .hung_detected
                            .fetch_add(1, Ordering::Relaxed);
                        drop(slot.handle.take());
                        schedule_respawn(slot, now_ms, &cfg);
                    }
                }
                if slot.handle.is_none() {
                    if let Some(at) = slot.respawn_at {
                        if now_ms >= at && slot.restarts < cfg.max_restarts {
                            respawn.push(i);
                        }
                    }
                }
            }
            for &i in &respawn {
                if s.shutdown.load(Ordering::Relaxed) {
                    break;
                }
                let fresh = spawn_worker(s, i);
                let slot = &mut slots[i];
                let restarts = slot.restarts + 1;
                *slot = fresh;
                slot.restarts = restarts;
                s.metrics.restarts.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

/// Exponential backoff: base * 2^restarts, capped.
fn schedule_respawn(
    slot: &mut WorkerSlot, now_ms: u64, cfg: &SupervisorConfig,
) {
    if slot.respawn_at.is_some() {
        return;
    }
    let base = cfg.backoff_base.as_millis() as u64;
    let cap = cfg.backoff_cap.as_millis() as u64;
    let exp = slot.restarts.min(20);
    let delay = base.saturating_mul(1u64 << exp).min(cap.max(base));
    slot.respawn_at = Some(now_ms + delay);
}

/// Width-halving bucket ladder up to the spec capacity, each bucket's
/// edge budget fully connected up to the spec's edge cap: capacity 32
/// with 256 edge slots gives [8/56, 16/240, 32/256].
fn default_buckets(
    max_atoms: usize, max_edges: usize, policy: BatchPolicy,
) -> Vec<BucketConfig> {
    let mut out: Vec<BucketConfig> = Vec::new();
    for w in [max_atoms / 4, max_atoms / 2, max_atoms] {
        if w == 0 || out.iter().any(|b| b.max_atoms == w) {
            continue;
        }
        let edges = (w * w.saturating_sub(1)).clamp(1, max_edges.max(1));
        out.push(BucketConfig { max_atoms: w, max_edges: edges, policy });
    }
    out
}

// ---------------------------------------------------------------------
// worker side
// ---------------------------------------------------------------------

fn worker_loop(s: &Arc<ServiceShared>, beat: &WorkerBeat) {
    loop {
        beat.busy.store(false, Ordering::Relaxed);
        beat.touch(s);
        let Some((bucket_idx, batch)) = s.queue.next_batch() else {
            return;
        };
        beat.busy.store(true, Ordering::Relaxed);
        beat.touch(s);
        // chaos site OUTSIDE the catch below: a `panic` policy here (or
        // escaping next_batch above) kills this worker thread outright,
        // exercising supervisor dead-detection + respawn; the batch
        // unwinds through reply-on-drop, so callers get Dropped, never
        // a hang.  An `error` policy fails the whole batch typed.
        match failpoint::check("svc.worker.tick") {
            Some(failpoint::Fault::Error(m)) => {
                fail_batch(s, batch, ExecFault::Backend(m));
                continue;
            }
            Some(failpoint::Fault::Nan) | None => {}
        }
        // a panicking backend must not kill the worker — and the moved
        // batch unwinds through the reply-on-drop guards, so every
        // caller gets Err(Dropped) instead of a hang
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            process_batch(s, beat, bucket_idx, batch);
        }));
        if outcome.is_err() {
            s.metrics.worker_panics.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Fail every request of a batch with the same typed execution fault.
fn fail_batch(s: &ServiceShared, batch: Vec<Pending>, fault: ExecFault) {
    for p in batch {
        s.metrics.failed.fetch_add(1, Ordering::Relaxed);
        p.finish(Err(ServiceError::Exec(fault.clone())));
    }
}

fn process_batch(
    s: &Arc<ServiceShared>, beat: &WorkerBeat, bucket_idx: usize,
    batch: Vec<Pending>,
) {
    // chaos site INSIDE the panic catch: `delay` stretches batch
    // execution (hang detection, cancel races), `error` fails the batch
    // typed while the worker survives
    match failpoint::check("svc.worker.batch") {
        Some(failpoint::Fault::Error(m)) => {
            fail_batch(s, batch, ExecFault::Backend(m));
            return;
        }
        Some(failpoint::Fault::Nan) | None => {}
    }
    let now = Instant::now();
    let mut evals: Vec<Pending> = Vec::new();
    let mut longs: Vec<Pending> = Vec::new();
    for p in batch {
        if p.canceled() {
            s.metrics.canceled.fetch_add(1, Ordering::Relaxed);
            p.finish(Err(ServiceError::Canceled));
        } else if p.expired(now) {
            s.metrics.expired.fetch_add(1, Ordering::Relaxed);
            p.finish(Err(ServiceError::DeadlineExceeded));
        } else if matches!(p.task, Task::Relax { .. } | Task::MdRollout { .. })
        {
            longs.push(p);
        } else {
            evals.push(p);
        }
    }
    if !evals.is_empty() {
        // group by endpoint so one padded batch never mixes two models
        // (the torn-batch guarantee), preserving submission order
        let mut groups: Vec<(Option<String>, Vec<Pending>)> = Vec::new();
        for p in evals {
            match groups.iter_mut().find(|(name, _)| *name == p.model) {
                Some((_, v)) => v.push(p),
                None => groups.push((p.model.clone(), vec![p])),
            }
        }
        for (name, group) in groups {
            run_eval_group(s, beat, bucket_idx, name.as_deref(), group);
        }
    }
    for p in longs {
        run_long(s, beat, bucket_idx, p);
    }
}

/// Evaluate a group of batchable tasks (same endpoint) as padded
/// chunks through the backend.
fn run_eval_group(
    s: &Arc<ServiceShared>, beat: &WorkerBeat, bucket_idx: usize,
    name: Option<&str>, group: Vec<Pending>,
) {
    let bucket = s.queue.bucket(bucket_idx);
    let mv = s.registry.resolve(name);
    if name.is_some() && mv.is_none() {
        // the endpoint vanished between submit and execution
        let msg = format!("unknown model endpoint '{}'", name.unwrap());
        for p in group {
            s.metrics.failed.fetch_add(1, Ordering::Relaxed);
            p.finish(Err(ServiceError::Rejected(msg.clone())));
        }
        return;
    }
    let model = mv.as_ref().map(|v| v.model.clone());
    let r_cut = model.as_ref().map(|m| m.cfg.r_cut).unwrap_or(s.r_cut);
    // flatten every task's structures into batch rows
    let mut graphs: Vec<Graph> = Vec::new();
    let mut spans: Vec<(usize, usize)> = Vec::new();
    for p in &group {
        let start = graphs.len();
        for st in p.task.structures() {
            graphs.push(Graph {
                pos: st.pos.clone(),
                species: st.species.clone(),
                energy: 0.0,
                forces: vec![[0.0; 3]; st.pos.len()],
            });
        }
        spans.push((start, graphs.len() - start));
    }
    // route into variant-sized chunks and execute; the model Arc
    // resolved above is used for EVERY chunk of this group
    let state = sync::read(&s.state).clone();
    type RowResult = std::result::Result<(f64, Vec<[f64; 3]>), ExecFault>;
    let mut row_results: Vec<RowResult> = Vec::with_capacity(graphs.len());
    let plan = s.router.plan(graphs.len());
    let mut offset = 0usize;
    for (variant, k) in plan {
        let chunk = &graphs[offset..offset + k];
        offset += k;
        beat.touch(s);
        let t_exec = Instant::now();
        let pb = PaddedBatch::from_graphs(
            chunk, variant.batch, bucket.max_atoms, bucket.max_edges, r_cut,
        );
        let res =
            s.backend.run(variant, &pb, state.as_ref(), model.as_ref());
        s.metrics
            .exec_latency
            .record_ns(t_exec.elapsed().as_nanos() as u64);
        observe_chunk(s, &pb, variant.batch, k);
        match res {
            Ok((energy, forces)) => {
                // ExecGuard: validate each row at the worker boundary.
                // A non-finite energy/force (f32 overflow, diverged
                // input, injected NaN) fails ONLY its own row — the
                // quarantine keeps batchmates' finite results intact.
                for (g_idx, g) in chunk.iter().enumerate() {
                    let na = g.pos.len();
                    let mut f = Vec::with_capacity(na);
                    let mut finite = energy[g_idx].is_finite();
                    for a in 0..na {
                        let base = (g_idx * bucket.max_atoms + a) * 3;
                        let row = [
                            forces[base] as f64,
                            forces[base + 1] as f64,
                            forces[base + 2] as f64,
                        ];
                        finite &= row.iter().all(|c| c.is_finite());
                        f.push(row);
                    }
                    if finite {
                        row_results.push(Ok((energy[g_idx] as f64, f)));
                    } else {
                        row_results.push(Err(ExecFault::NonFinite(format!(
                            "energy/forces for the {na}-atom structure in \
                             batch row {g_idx} are not finite; the row was \
                             quarantined"
                        ))));
                    }
                }
            }
            Err(e) => {
                let fault = ExecFault::Backend(format!("{e}"));
                for _ in 0..k {
                    row_results.push(Err(fault.clone()));
                }
            }
        }
    }
    // assemble the typed replies
    for (p, (start, len)) in group.into_iter().zip(spans) {
        let rows = &row_results[start..start + len];
        if let Some(e) = rows.iter().find_map(|r| r.as_ref().err()) {
            s.metrics.failed.fetch_add(1, Ordering::Relaxed);
            p.finish(Err(ServiceError::Exec(e.clone())));
            continue;
        }
        let lat = p.enqueued.elapsed();
        s.metrics.latency.record_ns(lat.as_nanos() as u64);
        let latency_s = lat.as_secs_f64();
        let id = p.id;
        let reply = match &p.task {
            Task::EnergyOnly { .. } => {
                let (energy, _) = rows[0].as_ref().unwrap();
                Reply::Energy(EnergyOut { id, energy: *energy, latency_s })
            }
            Task::EnergyForces { .. } => {
                let (energy, forces) = rows[0].as_ref().unwrap();
                Reply::EnergyForces(ForceResponse {
                    id,
                    energy: *energy,
                    forces: forces.clone(),
                    latency_s,
                })
            }
            Task::Batch { .. } => Reply::Batch(
                rows.iter()
                    .map(|r| {
                        let (energy, forces) = r.as_ref().unwrap();
                        ForceResponse {
                            id,
                            energy: *energy,
                            forces: forces.clone(),
                            latency_s,
                        }
                    })
                    .collect(),
            ),
            Task::Relax { .. } | Task::MdRollout { .. } => {
                unreachable!("long tasks are never batch-evaluated")
            }
        };
        s.metrics.responses.fetch_add(1, Ordering::Relaxed);
        p.finish(Ok(reply));
    }
}

/// Fold one executed chunk into the serving metrics (batch counters,
/// padding accounting, plan-cache gauges).
fn observe_chunk(
    s: &ServiceShared, pb: &PaddedBatch, row_slots: usize, occupied: usize,
) {
    s.metrics.batches.fetch_add(1, Ordering::Relaxed);
    s.metrics
        .batched_requests
        .fetch_add(occupied as u64, Ordering::Relaxed);
    s.metrics
        .padding_waste
        .fetch_add((row_slots - occupied) as u64, Ordering::Relaxed);
    let true_atoms: usize = pb.true_atoms.iter().sum();
    s.metrics.observe_padding(
        row_slots as u64,
        pb.n_atoms as u64,
        true_atoms as u64,
    );
    let cache = PlanCache::global();
    s.metrics.observe_plans(
        cache.builds() as u64,
        cache.hits() as u64,
        cache.len() as u64,
    );
}

/// Evaluate one structure through the backend (the relax/rollout force
/// provider when no learned model is resolved — surrogate or XLA).
fn eval_single(
    s: &ServiceShared, bucket: BucketConfig, state: &Arc<Vec<Tensor>>,
    pos: &[[f64; 3]], species: &[usize],
) -> Result<(f64, Vec<[f64; 3]>)> {
    let g = Graph {
        pos: pos.to_vec(),
        species: species.to_vec(),
        energy: 0.0,
        forces: vec![[0.0; 3]; pos.len()],
    };
    let variant = s.router.pick(1);
    let t_exec = Instant::now();
    let pb = PaddedBatch::from_graphs(
        std::slice::from_ref(&g), variant.batch, bucket.max_atoms,
        bucket.max_edges, s.r_cut,
    );
    let (energy, forces) =
        s.backend.run(variant, &pb, state.as_ref(), None)?;
    s.metrics
        .exec_latency
        .record_ns(t_exec.elapsed().as_nanos() as u64);
    observe_chunk(s, &pb, variant.batch, 1);
    let na = pos.len();
    let mut f = Vec::with_capacity(na);
    for a in 0..na {
        let base = a * 3;
        f.push([
            forces[base] as f64,
            forces[base + 1] as f64,
            forces[base + 2] as f64,
        ]);
    }
    Ok((energy[0] as f64, f))
}

/// Run a relax or rollout task on this worker.  Force evaluations go
/// through the resolved model's [`LearnedPotential`] (f64, zero-copy
/// scratch reuse along the trajectory) or, without a model, through the
/// backend one padded structure at a time.  Cancellation, deadline, and
/// backend errors surface as typed errors; rollout frames stream as the
/// integration advances.
fn run_long(
    s: &Arc<ServiceShared>, beat: &WorkerBeat, bucket_idx: usize, p: Pending,
) {
    let Pending { id, task, model: name, enqueued, deadline, cancel, reply } =
        p;
    let mut reply = reply;
    let bucket = s.queue.bucket(bucket_idx);
    let mv = s.registry.resolve(name.as_deref());
    if name.is_some() && mv.is_none() {
        s.metrics.failed.fetch_add(1, Ordering::Relaxed);
        reply.finish(Err(ServiceError::Rejected(format!(
            "unknown model endpoint '{}'",
            name.unwrap()
        ))));
        return;
    }
    let model = mv.as_ref().map(|v| v.model.clone());
    enum Long {
        Relax { max_steps: usize },
        Roll { steps: usize, dt: f64 },
    }
    let (pos0, species, kind) = match task {
        Task::Relax { structure, max_steps } => {
            (structure.pos, structure.species, Long::Relax { max_steps })
        }
        Task::MdRollout { structure, steps, dt } => {
            (structure.pos, structure.species, Long::Roll { steps, dt })
        }
        _ => unreachable!("run_long only sees Relax/MdRollout"),
    };
    if let Some(m) = &model {
        if species.len() > m.cfg.max_atoms {
            s.metrics.failed.fetch_add(1, Ordering::Relaxed);
            reply.finish(Err(ServiceError::Exec(ExecFault::Backend(
                format!(
                    "structure has {} atoms, model capacity is {}",
                    species.len(),
                    m.cfg.max_atoms
                ),
            ))));
            return;
        }
    }
    let mut learned =
        model.as_ref().map(|m| LearnedPotential::new(m.clone(), species.clone()));
    let state = sync::read(&s.state).clone();
    // runtime force-evaluation budget: the submit-time step caps bound
    // the REQUESTED work, this bounds the ACTUAL work — an integrator
    // bug (or a pathological surface) re-evaluating without advancing
    // must surface as a typed fault, not a worker pinned forever
    let budget: u64 = match &kind {
        Long::Relax { max_steps } => (*max_steps as u64 + 2) * 4,
        Long::Roll { steps, .. } => (*steps as u64 + 2) * 4,
    };
    let force_evals = Cell::new(0u64);
    // first typed error wins; once set, the provider returns zero forces
    // so FIRE/BAOAB wind down in O(1) steps instead of integrating noise
    let err: RefCell<Option<ServiceError>> = RefCell::new(None);
    let cancel_flag = cancel.clone();
    let species_for_provider = species.clone();
    let mut provider = |pos: &[[f64; 3]]| -> (f64, Vec<[f64; 3]>) {
        let zeros = (0.0, vec![[0.0f64; 3]; pos.len()]);
        if err.borrow().is_some() {
            return zeros;
        }
        beat.touch(s);
        if cancel_flag.load(Ordering::Relaxed) {
            *err.borrow_mut() = Some(ServiceError::Canceled);
            return zeros;
        }
        if deadline.map_or(false, |d| Instant::now() >= d) {
            *err.borrow_mut() = Some(ServiceError::DeadlineExceeded);
            return zeros;
        }
        force_evals.set(force_evals.get() + 1);
        if force_evals.get() > budget {
            *err.borrow_mut() = Some(ServiceError::Exec(
                ExecFault::BudgetExhausted(format!(
                    "long task spent {} force evaluations (budget {budget})",
                    force_evals.get()
                )),
            ));
            return zeros;
        }
        let (mut e, f) = match &mut learned {
            Some(lp) => lp.compute(pos),
            None => match eval_single(
                s, bucket, &state, pos, &species_for_provider,
            ) {
                Ok(r) => r,
                Err(backend_err) => {
                    *err.borrow_mut() = Some(ServiceError::Exec(
                        ExecFault::Backend(format!("{backend_err}")),
                    ));
                    return zeros;
                }
            },
        };
        // chaos site: `nan` poisons this evaluation's energy (the
        // containment below turns it into a typed NonFinite), `error`
        // fails the task typed
        match failpoint::check("svc.rollout.force") {
            Some(failpoint::Fault::Nan) => e = f64::NAN,
            Some(failpoint::Fault::Error(m)) => {
                *err.borrow_mut() =
                    Some(ServiceError::Exec(ExecFault::Backend(m)));
                return zeros;
            }
            None => {}
        }
        // ExecGuard for long tasks: a diverged or poisoned force
        // evaluation stops the trajectory with a typed fault instead of
        // integrating NaNs into every later frame
        if !e.is_finite()
            || f.iter().any(|v| v.iter().any(|c| !c.is_finite()))
        {
            *err.borrow_mut() =
                Some(ServiceError::Exec(ExecFault::NonFinite(format!(
                    "force evaluation {} returned non-finite \
                     energy/forces; trajectory stopped",
                    force_evals.get()
                ))));
            return zeros;
        }
        (e, f)
    };
    match kind {
        Long::Relax { max_steps } => {
            let res = fire_relax(
                &mut provider,
                &pos0,
                FireConfig { max_steps, ..Default::default() },
            );
            s.metrics.relaxes.fetch_add(1, Ordering::Relaxed);
            match err.into_inner() {
                Some(e) => {
                    count_failure(s, &e);
                    reply.finish(Err(e));
                }
                None => {
                    let lat = enqueued.elapsed();
                    s.metrics.latency.record_ns(lat.as_nanos() as u64);
                    s.metrics.responses.fetch_add(1, Ordering::Relaxed);
                    reply.finish(Ok(Reply::Relaxed(res)));
                }
            }
        }
        Long::Roll { steps, dt } => {
            // Thermostat::None consumes no randomness: the rollout is
            // deterministic and exactly reproducible client-side
            let mut rng = Rng::new(id);
            let mut md = Integrator::new_with(
                pos0, species.clone(), &mut provider, dt, Thermostat::None,
            );
            let mut streamed = 0usize;
            md.rollout_with(&mut provider, &mut rng, steps, |step, md| {
                if err.borrow().is_some() {
                    return false;
                }
                // frame-level ExecGuard: even with finite forces the
                // integration itself can diverge (dt too large); a
                // non-finite frame must never be streamed to the client
                let kinetic = md.kinetic_energy();
                if !md.potential_energy.is_finite()
                    || !kinetic.is_finite()
                    || md
                        .pos
                        .iter()
                        .any(|v| v.iter().any(|c| !c.is_finite()))
                {
                    *err.borrow_mut() = Some(ServiceError::Exec(
                        ExecFault::NonFinite(format!(
                            "integration diverged at step {step}: frame \
                             contains non-finite values"
                        )),
                    ));
                    return false;
                }
                reply.frame(Frame {
                    step,
                    time: (step + 1) as f64 * dt,
                    energy: md.potential_energy,
                    kinetic: md.kinetic_energy(),
                    pos: md.pos.clone(),
                });
                streamed += 1;
                s.metrics.frames.fetch_add(1, Ordering::Relaxed);
                true
            });
            s.metrics.rollouts.fetch_add(1, Ordering::Relaxed);
            match err.into_inner() {
                Some(e) => {
                    count_failure(s, &e);
                    reply.finish(Err(e));
                }
                None => {
                    let lat = enqueued.elapsed();
                    s.metrics.latency.record_ns(lat.as_nanos() as u64);
                    s.metrics.responses.fetch_add(1, Ordering::Relaxed);
                    reply.finish(Ok(Reply::Rollout(RolloutSummary {
                        id,
                        steps: streamed,
                        final_pos: md.pos.clone(),
                        final_energy: md.total_energy(),
                    })));
                }
            }
        }
    }
}

fn count_failure(s: &ServiceShared, e: &ServiceError) {
    match e {
        ServiceError::Canceled => {
            s.metrics.canceled.fetch_add(1, Ordering::Relaxed);
        }
        ServiceError::DeadlineExceeded => {
            s.metrics.expired.fetch_add(1, Ordering::Relaxed);
        }
        _ => {
            s.metrics.failed.fetch_add(1, Ordering::Relaxed);
        }
    }
}
