//! Executable-variant router: the artifacts ship several batch-size
//! variants of the same model (`ff_fwd_B1`, `ff_fwd_B4`, `ff_fwd_B8`);
//! the router picks the cheapest cover for a pending batch.

/// A compiled variant (batch capacity + name).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Variant {
    pub name: String,
    pub batch: usize,
}

/// Router over batch-size variants.
#[derive(Clone, Debug, Default)]
pub struct Router {
    /// sorted ascending by batch
    variants: Vec<Variant>,
}

impl Router {
    pub fn new(mut variants: Vec<Variant>) -> Self {
        assert!(!variants.is_empty(), "router needs at least one variant");
        variants.sort_by_key(|v| v.batch);
        Router { variants }
    }

    pub fn variants(&self) -> &[Variant] {
        &self.variants
    }

    pub fn max_batch(&self) -> usize {
        self.variants.last().map(|v| v.batch).unwrap_or(0)
    }

    /// Smallest variant with capacity >= n (or the largest available).
    pub fn pick(&self, n: usize) -> &Variant {
        self.variants
            .iter()
            .find(|v| v.batch >= n)
            .unwrap_or_else(|| self.variants.last().unwrap())
    }

    /// Split n requests into chunks, each assigned the smallest fitting
    /// variant: greedy largest-first then a tight tail.
    pub fn plan(&self, n: usize) -> Vec<(&Variant, usize)> {
        let mut plan = Vec::new();
        let mut left = n;
        let biggest = self.max_batch();
        while left > 0 {
            if left >= biggest {
                plan.push((self.pick(biggest), biggest));
                left -= biggest;
            } else {
                let v = self.pick(left);
                plan.push((v, left));
                left = 0;
            }
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, PropConfig};

    fn router() -> Router {
        Router::new(vec![
            Variant { name: "b4".into(), batch: 4 },
            Variant { name: "b1".into(), batch: 1 },
            Variant { name: "b8".into(), batch: 8 },
        ])
    }

    #[test]
    fn picks_smallest_fitting() {
        let r = router();
        assert_eq!(r.pick(1).batch, 1);
        assert_eq!(r.pick(2).batch, 4);
        assert_eq!(r.pick(4).batch, 4);
        assert_eq!(r.pick(5).batch, 8);
        assert_eq!(r.pick(100).batch, 8); // saturates at largest
    }

    #[test]
    fn plan_covers_exactly() {
        let r = router();
        for n in 1..40 {
            let plan = r.plan(n);
            let total: usize = plan.iter().map(|(_, k)| k).sum();
            assert_eq!(total, n, "plan must cover all requests");
            for (v, k) in &plan {
                assert!(v.batch >= *k, "chunk exceeds variant capacity");
            }
        }
    }

    #[test]
    fn plan_is_greedy_minimal_padding_property() {
        check("router-padding-bounded", PropConfig { cases: 64, seed: 1 },
              |rng, _| {
            let r = router();
            let n = 1 + rng.below(64);
            let plan = r.plan(n);
            let padded: usize = plan.iter().map(|(v, _)| v.batch).sum();
            // waste is bounded by the largest variant
            if padded - n < 8 {
                Ok(())
            } else {
                Err(format!("padding waste {} for n={n}", padded - n))
            }
        });
    }

    #[test]
    #[should_panic]
    fn empty_router_panics() {
        let _ = Router::new(vec![]);
    }
}
