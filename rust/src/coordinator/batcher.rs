//! Dynamic batching, in two generations:
//!
//! * [`Batcher`] — the legacy single global queue over [`Envelope`]s
//!   (kept as the compatibility substrate and for its tests).  Fixed
//!   here: `close()` drains and **fails** every still-queued request
//!   deterministically (the old close left them to luck — with no live
//!   worker they leaked a forever-blocked `rx.recv()`), and the
//!   size-or-deadline flush honors `max_wait` measured from the
//!   *oldest* queued envelope even while new arrivals keep trickling in
//!   (a slow-filling queue must flush on the first request's clock, not
//!   the last's).
//!
//! * [`BucketedBatcher`] — the serving queue of the typed protocol:
//!   requests are routed to per-atom-count **shape buckets**, each with
//!   its own queue and [`BatchPolicy`], and each flushed batch is padded
//!   only to its bucket's width.  Padding waste stops scaling with the
//!   largest structure in flight: a 4-atom structure queued behind a
//!   32-atom one no longer pays a 32-slot pad.
//!
//! Both share the size-or-deadline flush rule (fill batches for
//! throughput, bound queueing delay for latency) and backpressure via a
//! queue cap.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::request::{Envelope, Pending, ServiceError};
use crate::util::{failpoint, sync};

/// Why [`BucketedBatcher::push`] refused a request.  The service maps
/// these to distinct [`ServiceError`]s: `NoFit` is a permanent
/// rejection, `Full` is retryable backpressure, `Closed` is shutdown.
#[derive(Clone, Debug, PartialEq)]
pub enum PushError {
    /// No bucket is wide enough for the request's largest structure.
    NoFit(String),
    /// The target bucket hit its `max_queue` cap.
    Full { bucket: usize, depth: usize },
    /// The queue was closed by shutdown.
    Closed,
}

impl std::fmt::Display for PushError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PushError::NoFit(m) => write!(f, "{m}"),
            PushError::Full { bucket, depth } => write!(
                f,
                "bucket {bucket} is full (backpressure, depth {depth})"
            ),
            PushError::Closed => write!(f, "service is shut down"),
        }
    }
}

/// Flush policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// flush as soon as this many requests are queued
    pub max_batch: usize,
    /// flush when the oldest request has waited this long
    pub max_wait: Duration,
    /// reject new requests beyond this depth (backpressure)
    pub max_queue: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            max_queue: 1024,
        }
    }
}

// ---------------------------------------------------------------------
// legacy global queue
// ---------------------------------------------------------------------

struct Inner {
    queue: VecDeque<Envelope>,
    closed: bool,
}

/// Thread-safe dynamic batcher (legacy single global queue).
pub struct Batcher {
    policy: BatchPolicy,
    inner: Mutex<Inner>,
    cv: Condvar,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher {
            policy,
            inner: Mutex::new(Inner { queue: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
        }
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    /// Enqueue; `Err` when the queue is full (backpressure) or closed.
    pub fn push(&self, env: Envelope) -> Result<(), Envelope> {
        let mut g = sync::lock(&self.inner);
        if g.closed || g.queue.len() >= self.policy.max_queue {
            return Err(env);
        }
        g.queue.push_back(env);
        self.cv.notify_all();
        Ok(())
    }

    pub fn len(&self) -> usize {
        sync::lock(&self.inner).queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close the queue: wakes all waiting workers AND deterministically
    /// fails every still-pending request with an `Err` reply.  After
    /// `close()` returns, no caller can be left waiting on a request
    /// that no worker will ever serve.
    pub fn close(&self) {
        let drained: Vec<Envelope> = {
            let mut g = sync::lock(&self.inner);
            g.closed = true;
            g.queue.drain(..).collect()
        };
        self.cv.notify_all();
        for mut env in drained {
            env.reply.send(Err(
                "service closed while the request was still queued"
                    .to_string(),
            ));
        }
    }

    /// Block until a batch is ready per the policy (or the queue
    /// closes).  Returns `None` once closed (close() already failed any
    /// leftover requests, so there is nothing to drain).  FIFO order is
    /// preserved, and the deadline flush always runs on the OLDEST
    /// envelope's clock: new arrivals never re-arm the timer.
    pub fn next_batch(&self) -> Option<Vec<Envelope>> {
        let mut g = sync::lock(&self.inner);
        loop {
            if g.closed {
                return None;
            }
            if let Some(front) = g.queue.front() {
                let waited = front.enqueued.elapsed();
                if g.queue.len() >= self.policy.max_batch
                    || waited >= self.policy.max_wait
                {
                    let take = g.queue.len().min(self.policy.max_batch);
                    return Some(g.queue.drain(..take).collect());
                }
                // wait out the oldest envelope's remaining deadline (or
                // a new arrival that might complete the batch)
                let remain = self.policy.max_wait - waited;
                let (g2, _timeout) = sync::cv_wait_timeout(&self.cv, g, remain);
                g = g2;
            } else {
                g = sync::cv_wait(&self.cv, g);
            }
        }
    }

    /// Non-blocking: take up to max_batch requests if any are queued.
    pub fn try_batch(&self) -> Option<Vec<Envelope>> {
        let mut g = sync::lock(&self.inner);
        if g.queue.is_empty() {
            return None;
        }
        let take = g.queue.len().min(self.policy.max_batch);
        Some(g.queue.drain(..take).collect())
    }

    /// Time the oldest queued request has been waiting.
    pub fn oldest_wait(&self) -> Option<Duration> {
        let g = sync::lock(&self.inner);
        g.queue.front().map(|e| e.enqueued.elapsed())
    }
}

// ---------------------------------------------------------------------
// shape-bucketed queue (the typed-protocol serving queue)
// ---------------------------------------------------------------------

/// One shape bucket: requests whose largest structure fits in
/// `max_atoms` are queued here and padded to exactly this width.
#[derive(Clone, Copy, Debug)]
pub struct BucketConfig {
    /// padding width of every batch flushed from this bucket
    pub max_atoms: usize,
    /// edge-slot budget of every batch flushed from this bucket
    pub max_edges: usize,
    pub policy: BatchPolicy,
}

struct BucketedInner {
    queues: Vec<VecDeque<Pending>>,
    closed: bool,
}

/// Per-atom-count-bucket queues with per-bucket flush policies.  A
/// request is routed to the smallest bucket that fits its largest
/// structure; each bucket flushes by its own size-or-deadline rule
/// (deadline measured from the bucket's OLDEST request), so small
/// structures neither wait on nor pad up to the big ones.
pub struct BucketedBatcher {
    buckets: Vec<BucketConfig>,
    inner: Mutex<BucketedInner>,
    cv: Condvar,
}

impl BucketedBatcher {
    /// Buckets are sorted ascending by `max_atoms`; at least one is
    /// required.
    pub fn new(mut buckets: Vec<BucketConfig>) -> BucketedBatcher {
        assert!(!buckets.is_empty(), "need at least one shape bucket");
        buckets.sort_by_key(|b| b.max_atoms);
        let n = buckets.len();
        BucketedBatcher {
            buckets,
            inner: Mutex::new(BucketedInner {
                queues: (0..n).map(|_| VecDeque::new()).collect(),
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    pub fn buckets(&self) -> &[BucketConfig] {
        &self.buckets
    }

    pub fn bucket(&self, idx: usize) -> BucketConfig {
        self.buckets[idx]
    }

    /// Largest structure any bucket can hold.
    pub fn max_atoms(&self) -> usize {
        self.buckets.last().map(|b| b.max_atoms).unwrap_or(0)
    }

    /// Index of the smallest bucket that fits `n_atoms`.
    pub fn bucket_for(&self, n_atoms: usize) -> Option<usize> {
        self.buckets.iter().position(|b| b.max_atoms >= n_atoms)
    }

    /// Enqueue into the smallest fitting bucket; `Err` carries the
    /// rejected request back with a typed reason.
    pub fn push(&self, p: Pending) -> Result<(), (Pending, PushError)> {
        let idx = match self.bucket_for(p.n_atoms()) {
            Some(i) => i,
            None => {
                let msg = format!(
                    "no bucket fits a {}-atom structure (largest bucket \
                     holds {})",
                    p.n_atoms(),
                    self.max_atoms()
                );
                return Err((p, PushError::NoFit(msg)));
            }
        };
        let mut g = sync::lock(&self.inner);
        if g.closed {
            return Err((p, PushError::Closed));
        }
        if g.queues[idx].len() >= self.buckets[idx].policy.max_queue {
            return Err((
                p,
                PushError::Full {
                    bucket: idx,
                    depth: self.buckets[idx].policy.max_queue,
                },
            ));
        }
        g.queues[idx].push_back(p);
        self.cv.notify_all();
        Ok(())
    }

    /// Total queued requests across every bucket.
    pub fn len(&self) -> usize {
        sync::lock(&self.inner).queues.iter().map(|q| q.len()).sum()
    }

    /// Total queue capacity (sum of every bucket's `max_queue`) — the
    /// denominator for admission-control watermarks.
    pub fn capacity(&self) -> usize {
        self.buckets.iter().map(|b| b.policy.max_queue).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Block until some bucket is flushable (size reached, or its
    /// OLDEST request hit the bucket's `max_wait`).  Returns the bucket
    /// index and the FIFO batch, or `None` once the queue is closed.
    ///
    /// Selection is latency-first: among OVERDUE buckets the
    /// most-overdue wins (their fronts age, so under sustained overload
    /// buckets alternate by age instead of one starving the others);
    /// a merely-full bucket flushes immediately only when nothing is
    /// overdue — a full small bucket can therefore never starve a
    /// larger bucket past its `max_wait`.
    pub fn next_batch(&self) -> Option<(usize, Vec<Pending>)> {
        let mut g = sync::lock(&self.inner);
        loop {
            if g.closed {
                return None;
            }
            let now = Instant::now();
            let mut overdue: Option<(usize, Duration)> = None;
            let mut full: Option<usize> = None;
            let mut min_remain: Option<Duration> = None;
            let mut any_queued = false;
            for (i, q) in g.queues.iter().enumerate() {
                let front = match q.front() {
                    Some(f) => f,
                    None => continue,
                };
                any_queued = true;
                let pol = &self.buckets[i].policy;
                let waited = now.saturating_duration_since(front.enqueued);
                if waited >= pol.max_wait {
                    let over = waited - pol.max_wait;
                    if overdue.map_or(true, |(_, best)| over > best) {
                        overdue = Some((i, over));
                    }
                } else {
                    if q.len() >= pol.max_batch && full.is_none() {
                        full = Some(i);
                    }
                    let remain = pol.max_wait - waited;
                    min_remain =
                        Some(min_remain.map_or(remain, |m| m.min(remain)));
                }
            }
            let ready = overdue.map(|(i, _)| i).or(full);
            if let Some(i) = ready {
                let take =
                    g.queues[i].len().min(self.buckets[i].policy.max_batch);
                let batch: Vec<Pending> = g.queues[i].drain(..take).collect();
                // chaos site: a `panic` policy here unwinds while the
                // queue mutex is held, poisoning it — the recovery path
                // (sync::lock everywhere) is what keeps the service
                // alive afterwards.  The drained batch's reply slots
                // fire Dropped on unwind, so no caller hangs.
                let _ = failpoint::check("svc.batcher.flush");
                return Some((i, batch));
            }
            g = if any_queued {
                match min_remain {
                    Some(d) => sync::cv_wait_timeout(&self.cv, g, d).0,
                    None => sync::cv_wait(&self.cv, g),
                }
            } else {
                sync::cv_wait(&self.cv, g)
            };
        }
    }

    /// Close every bucket: wakes all workers and deterministically fails
    /// every still-queued request with [`ServiceError::Shutdown`].
    pub fn close(&self) {
        let drained: Vec<Pending> = {
            let mut g = sync::lock(&self.inner);
            g.closed = true;
            let mut v = Vec::new();
            for q in g.queues.iter_mut() {
                v.extend(q.drain(..));
            }
            v
        };
        self.cv.notify_all();
        for p in drained {
            p.finish(Err(ServiceError::Shutdown));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{
        ForceRequest, ReplyGuard, ReplyMsg, ReplySlot, Structure, Task,
    };
    use std::sync::atomic::AtomicBool;
    use std::sync::mpsc::{channel, Receiver};
    use std::sync::Arc;

    fn env(id: u64) -> Envelope {
        let (tx, _rx) = channel();
        Envelope {
            req: ForceRequest { id, pos: vec![], species: vec![] },
            reply: ReplyGuard::new(tx),
            enqueued: Instant::now(),
        }
    }

    fn env_with_rx(id: u64) -> (Envelope, Receiver<Result<crate::coordinator::request::ForceResponse, String>>) {
        let (tx, rx) = channel();
        (
            Envelope {
                req: ForceRequest { id, pos: vec![], species: vec![] },
                reply: ReplyGuard::new(tx),
                enqueued: Instant::now(),
            },
            rx,
        )
    }

    fn pending(id: u64, n_atoms: usize) -> (Pending, Receiver<ReplyMsg>) {
        let (tx, rx) = channel();
        (
            Pending {
                id,
                task: Task::EnergyForces {
                    structure: Structure {
                        pos: vec![[0.0; 3]; n_atoms],
                        species: vec![0; n_atoms],
                    },
                },
                model: None,
                enqueued: Instant::now(),
                deadline: None,
                cancel: Arc::new(AtomicBool::new(false)),
                reply: ReplySlot::new(tx),
            },
            rx,
        )
    }

    #[test]
    fn flushes_on_size() {
        let b = Batcher::new(BatchPolicy {
            max_batch: 3,
            max_wait: Duration::from_secs(10),
            max_queue: 100,
        });
        for i in 0..3 {
            b.push(env(i)).map_err(|_| ()).unwrap();
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 3);
        // FIFO
        assert_eq!(batch[0].req.id, 0);
        assert_eq!(batch[2].req.id, 2);
    }

    #[test]
    fn flushes_on_deadline() {
        let b = Batcher::new(BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_millis(5),
            max_queue: 100,
        });
        b.push(env(1)).map_err(|_| ()).unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(4));
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn flush_deadline_runs_on_the_oldest_not_the_newest() {
        // a slow-filling queue: new envelopes keep arriving every few
        // ms, never reaching max_batch.  The flush must fire ~max_wait
        // after the FIRST envelope — if arrivals re-armed the timer the
        // batch would be starved for the whole push storm.
        let b = Arc::new(Batcher::new(BatchPolicy {
            max_batch: 1000,
            max_wait: Duration::from_millis(50),
            max_queue: 10_000,
        }));
        let b2 = b.clone();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let pusher = std::thread::spawn(move || {
            for i in 0..200u64 {
                if stop2.load(std::sync::atomic::Ordering::Relaxed) {
                    break;
                }
                let _ = b2.push(env(i));
                std::thread::sleep(Duration::from_millis(5));
            }
        });
        // wait until the first envelope is actually queued, THEN time
        while b.is_empty() {
            std::thread::sleep(Duration::from_millis(1));
        }
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        let elapsed = t0.elapsed();
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        assert_eq!(batch[0].req.id, 0, "oldest first");
        assert!(
            elapsed < Duration::from_millis(400),
            "flush starved by slow-filling queue: waited {elapsed:?} \
             (max_wait is 50ms)"
        );
        pusher.join().unwrap();
    }

    #[test]
    fn backpressure_rejects() {
        let b = Batcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            max_queue: 2,
        });
        assert!(b.push(env(0)).is_ok());
        assert!(b.push(env(1)).is_ok());
        assert!(b.push(env(2)).is_err());
    }

    #[test]
    fn close_unblocks_workers() {
        let b = Arc::new(Batcher::new(BatchPolicy::default()));
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(20));
        b.close();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn close_fails_pending_requests_deterministically() {
        // the other half of the client-hang fix: close() with a
        // non-empty queue must fail every queued request THEN AND THERE
        // — even with zero live workers, no caller is left hanging
        let b = Batcher::new(BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_secs(60),
            max_queue: 100,
        });
        let (e0, rx0) = env_with_rx(0);
        let (e1, rx1) = env_with_rx(1);
        b.push(e0).map_err(|_| ()).unwrap();
        b.push(e1).map_err(|_| ()).unwrap();
        b.close();
        for rx in [rx0, rx1] {
            let got = rx
                .recv_timeout(Duration::from_secs(5))
                .expect("close must reply, not leak the request");
            assert!(got.is_err());
            assert!(got.unwrap_err().contains("closed"));
        }
        // and the queue really is drained: workers see None
        assert!(b.next_batch().is_none());
        assert!(b.is_empty());
    }

    #[test]
    fn preserves_fifo_across_batches() {
        let b = Batcher::new(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_millis(1),
            max_queue: 100,
        });
        for i in 0..5 {
            b.push(env(i)).map_err(|_| ()).unwrap();
        }
        let mut seen = Vec::new();
        while let Some(batch) = b.try_batch() {
            for e in batch {
                seen.push(e.req.id);
            }
        }
        assert_eq!(seen, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn push_after_close_fails() {
        let b = Batcher::new(BatchPolicy::default());
        b.close();
        assert!(b.push(env(0)).is_err());
    }

    // -- bucketed ------------------------------------------------------

    fn two_buckets(small_wait_ms: u64, big_wait_ms: u64) -> BucketedBatcher {
        BucketedBatcher::new(vec![
            BucketConfig {
                max_atoms: 8,
                max_edges: 56,
                policy: BatchPolicy {
                    max_batch: 4,
                    max_wait: Duration::from_millis(small_wait_ms),
                    max_queue: 64,
                },
            },
            BucketConfig {
                max_atoms: 32,
                max_edges: 256,
                policy: BatchPolicy {
                    max_batch: 4,
                    max_wait: Duration::from_millis(big_wait_ms),
                    max_queue: 64,
                },
            },
        ])
    }

    #[test]
    fn routes_by_atom_count() {
        let b = two_buckets(1000, 1000);
        assert_eq!(b.bucket_for(1), Some(0));
        assert_eq!(b.bucket_for(8), Some(0));
        assert_eq!(b.bucket_for(9), Some(1));
        assert_eq!(b.bucket_for(32), Some(1));
        assert_eq!(b.bucket_for(33), None);
        assert_eq!(b.max_atoms(), 32);
    }

    #[test]
    fn too_large_is_rejected_with_the_request() {
        let b = two_buckets(1000, 1000);
        let (p, _rx) = pending(0, 40);
        let (p, why) = b.push(p).unwrap_err();
        assert_eq!(p.id, 0);
        assert!(matches!(&why, PushError::NoFit(m) if m.contains("no bucket")),
                "{why}");
    }

    #[test]
    fn full_bucket_reports_typed_backpressure() {
        let b = BucketedBatcher::new(vec![BucketConfig {
            max_atoms: 8,
            max_edges: 56,
            policy: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_secs(60),
                max_queue: 1,
            },
        }]);
        let (p0, _r0) = pending(0, 4);
        b.push(p0).map_err(|_| ()).unwrap();
        let (p1, _r1) = pending(1, 4);
        let (_, why) = b.push(p1).unwrap_err();
        assert_eq!(why, PushError::Full { bucket: 0, depth: 1 });
        assert_eq!(b.capacity(), 1);
    }

    // NOTE: the poisoned-mutex recovery path (svc.batcher.flush panic
    // failpoint) is exercised in tests/chaos_conformance.rs, which
    // serializes failpoint use — the registry is process-global, so
    // arming a panic policy here could fire in a concurrently running
    // unit test's worker instead.

    #[test]
    fn buckets_flush_independently() {
        // the small bucket fills to its max_batch and flushes at once;
        // the big bucket's lone request waits out its own deadline
        let b = two_buckets(2000, 2000);
        let mut rxs = Vec::new();
        for i in 0..4 {
            let (p, rx) = pending(i, 4);
            b.push(p).map_err(|_| ()).unwrap();
            rxs.push(rx);
        }
        let (p_big, _rx_big) = pending(99, 20);
        b.push(p_big).map_err(|_| ()).unwrap();
        let t0 = Instant::now();
        let (idx, batch) = b.next_batch().unwrap();
        assert_eq!(idx, 0, "full small bucket flushes first");
        assert_eq!(batch.len(), 4);
        assert!(t0.elapsed() < Duration::from_millis(500),
                "size flush must not wait for any deadline");
        assert_eq!(b.len(), 1, "big bucket still queued");
    }

    #[test]
    fn per_bucket_deadline_uses_each_buckets_oldest() {
        // small bucket: long deadline; big bucket: short — the big one
        // must flush first even though the small request is older
        let b = two_buckets(1500, 20);
        let (p_small, _r1) = pending(1, 4);
        b.push(p_small).map_err(|_| ()).unwrap();
        std::thread::sleep(Duration::from_millis(5));
        let (p_big, _r2) = pending(2, 20);
        b.push(p_big).map_err(|_| ()).unwrap();
        let (idx, batch) = b.next_batch().unwrap();
        assert_eq!(idx, 1, "short-deadline bucket flushes first");
        assert_eq!(batch[0].id, 2);
    }

    #[test]
    fn full_small_bucket_cannot_starve_an_overdue_big_bucket() {
        // small bucket: effectively no deadline, kept full; big bucket:
        // 30ms deadline.  Once the big request is overdue it must win
        // the next flush even though the small bucket is still full.
        let b = BucketedBatcher::new(vec![
            BucketConfig {
                max_atoms: 8,
                max_edges: 56,
                policy: BatchPolicy {
                    max_batch: 2,
                    max_wait: Duration::from_secs(60),
                    max_queue: 64,
                },
            },
            BucketConfig {
                max_atoms: 32,
                max_edges: 256,
                policy: BatchPolicy {
                    max_batch: 4,
                    max_wait: Duration::from_millis(30),
                    max_queue: 64,
                },
            },
        ]);
        let mut rxs = Vec::new();
        for i in 0..6 {
            let (p, rx) = pending(i, 4);
            b.push(p).map_err(|_| ()).unwrap();
            rxs.push(rx);
        }
        let (p_big, _rx_big) = pending(99, 20);
        b.push(p_big).map_err(|_| ()).unwrap();
        // nothing overdue yet: full small-bucket flushes drain first
        let (idx, _) = b.next_batch().unwrap();
        assert_eq!(idx, 0);
        std::thread::sleep(Duration::from_millis(40));
        // the big request is now overdue; the still-full small bucket
        // must not starve it
        let (idx, batch) = b.next_batch().unwrap();
        assert_eq!(idx, 1, "overdue bucket must beat a merely-full one");
        assert_eq!(batch[0].id, 99);
        assert_eq!(b.len(), 4, "small bucket still holds its backlog");
    }

    #[test]
    fn close_fails_all_buckets_pending() {
        let b = two_buckets(60_000, 60_000);
        let (p1, rx1) = pending(1, 4);
        let (p2, rx2) = pending(2, 20);
        b.push(p1).map_err(|_| ()).unwrap();
        b.push(p2).map_err(|_| ()).unwrap();
        b.close();
        for rx in [rx1, rx2] {
            match rx.recv_timeout(Duration::from_secs(5)).unwrap() {
                ReplyMsg::Done(Err(ServiceError::Shutdown)) => {}
                other => panic!("expected Shutdown, got {other:?}"),
            }
        }
        assert!(b.next_batch().is_none());
        // push after close is rejected
        let (p3, _rx3) = pending(3, 4);
        assert!(b.push(p3).is_err());
    }

    #[test]
    fn bucketed_close_unblocks_waiting_worker() {
        let b = Arc::new(two_buckets(60_000, 60_000));
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(20));
        b.close();
        assert!(h.join().unwrap().is_none());
    }
}
