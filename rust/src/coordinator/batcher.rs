//! Dynamic batcher: Condvar-guarded queue with a size-or-deadline flush
//! policy (the standard serving trade-off: fill batches for throughput,
//! bound queueing delay for latency) and backpressure via a queue cap.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use super::request::Envelope;

/// Flush policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// flush as soon as this many requests are queued
    pub max_batch: usize,
    /// flush when the oldest request has waited this long
    pub max_wait: Duration,
    /// reject new requests beyond this depth (backpressure)
    pub max_queue: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            max_queue: 1024,
        }
    }
}

struct Inner {
    queue: VecDeque<Envelope>,
    closed: bool,
}

/// Thread-safe dynamic batcher.
pub struct Batcher {
    policy: BatchPolicy,
    inner: Mutex<Inner>,
    cv: Condvar,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher {
            policy,
            inner: Mutex::new(Inner { queue: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
        }
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    /// Enqueue; `Err` when the queue is full (backpressure) or closed.
    pub fn push(&self, env: Envelope) -> Result<(), Envelope> {
        let mut g = self.inner.lock().unwrap();
        if g.closed || g.queue.len() >= self.policy.max_queue {
            return Err(env);
        }
        g.queue.push_back(env);
        self.cv.notify_one();
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close the queue; wakes all waiting workers.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Block until a batch is ready per the policy (or the queue closes).
    /// Returns `None` when closed and drained.  FIFO order is preserved.
    pub fn next_batch(&self) -> Option<Vec<Envelope>> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if !g.queue.is_empty() {
                let oldest = g.queue.front().unwrap().enqueued;
                let waited = oldest.elapsed();
                if g.queue.len() >= self.policy.max_batch
                    || waited >= self.policy.max_wait
                    || g.closed
                {
                    let take = g.queue.len().min(self.policy.max_batch);
                    return Some(g.queue.drain(..take).collect());
                }
                // wait out the remaining deadline (or a new arrival)
                let remain = self.policy.max_wait - waited;
                let (g2, _timeout) = self.cv.wait_timeout(g, remain).unwrap();
                g = g2;
            } else {
                if g.closed {
                    return None;
                }
                g = self.cv.wait(g).unwrap();
            }
        }
    }

    /// Non-blocking: take up to max_batch requests if any are queued.
    pub fn try_batch(&self) -> Option<Vec<Envelope>> {
        let mut g = self.inner.lock().unwrap();
        if g.queue.is_empty() {
            return None;
        }
        let take = g.queue.len().min(self.policy.max_batch);
        Some(g.queue.drain(..take).collect())
    }

    /// Time the oldest queued request has been waiting.
    pub fn oldest_wait(&self) -> Option<Duration> {
        let g = self.inner.lock().unwrap();
        g.queue.front().map(|e| e.enqueued.elapsed())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::ForceRequest;
    use std::sync::mpsc::channel;
    use std::time::Instant;
    use std::sync::Arc;

    fn env(id: u64) -> Envelope {
        let (tx, _rx) = channel();
        Envelope {
            req: ForceRequest { id, pos: vec![], species: vec![] },
            reply: tx,
            enqueued: Instant::now(),
        }
    }

    #[test]
    fn flushes_on_size() {
        let b = Batcher::new(BatchPolicy {
            max_batch: 3,
            max_wait: Duration::from_secs(10),
            max_queue: 100,
        });
        for i in 0..3 {
            b.push(env(i)).map_err(|_| ()).unwrap();
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 3);
        // FIFO
        assert_eq!(batch[0].req.id, 0);
        assert_eq!(batch[2].req.id, 2);
    }

    #[test]
    fn flushes_on_deadline() {
        let b = Batcher::new(BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_millis(5),
            max_queue: 100,
        });
        b.push(env(1)).map_err(|_| ()).unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(4));
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn backpressure_rejects() {
        let b = Batcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            max_queue: 2,
        });
        assert!(b.push(env(0)).is_ok());
        assert!(b.push(env(1)).is_ok());
        assert!(b.push(env(2)).is_err());
    }

    #[test]
    fn close_unblocks_workers() {
        let b = Arc::new(Batcher::new(BatchPolicy::default()));
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(20));
        b.close();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn preserves_fifo_across_batches() {
        let b = Batcher::new(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_millis(1),
            max_queue: 100,
        });
        for i in 0..5 {
            b.push(env(i)).map_err(|_| ()).unwrap();
        }
        let mut seen = Vec::new();
        while let Some(batch) = b.try_batch() {
            for e in batch {
                seen.push(e.req.id);
            }
        }
        assert_eq!(seen, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn push_after_close_fails() {
        let b = Batcher::new(BatchPolicy::default());
        b.close();
        assert!(b.push(env(0)).is_err());
    }
}
