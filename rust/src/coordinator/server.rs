//! The force-field serving coordinator: worker pool over the dynamic
//! batcher, routing each flushed batch to the smallest compiled variant.
//!
//! Inference is pluggable through [`Backend`]: every server is started
//! by the ONE constructor [`ForceFieldServer::start_with`], which takes
//! a [`BackendSpec`] (backend + variants + state + padding shape) and
//! owns the worker/queue setup.  [`ForceFieldServer::start`] (compiled
//! PJRT artifacts) and [`ForceFieldServer::start_native`] (the native
//! Gaunt-TP backend) are thin spec builders over it.  The native path
//! serves either the learned [`Model`] or an analytic equivariant
//! surrogate evaluated entirely with the native O(L^3) Gaunt pipeline —
//! every batch resolves its op through [`PlanCache::op`] and runs the
//! generic batched driver of [`crate::tp::op`], so the full coordinator
//! stack (batcher -> router -> worker pool -> backend) is exercisable
//! offline.  Plan-cache statistics (builds/hits/entries per [`OpKey`])
//! are folded into the server [`Metrics`] after every batch, so serving
//! can observe plan churn.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, RwLock};
use std::thread::JoinHandle;
use std::time::Instant;

use super::batcher::{BatchPolicy, Batcher};
use super::metrics::Metrics;
use super::request::{Envelope, ForceRequest, ForceResponse};
use super::router::{Router, Variant};
use crate::data::{Graph, PaddedBatch};
use crate::err;
use crate::model::{batch_row_len, energy_forces_batch_par, GraphRef, Model};
use crate::num_coeffs;
use crate::runtime::{Engine, Tensor};
use crate::so3::sh::real_sh_all_xyz;
use crate::tp::engine::{CacheStats, OpKey, PlanCache};
use crate::tp::op::{apply_batch_par, BatchInputs};
use crate::tp::ConvMethod;
use crate::util::error::Result;
use crate::util::json::Json;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub policy: BatchPolicy,
    pub n_workers: usize,
    /// neighbor cutoff used to build edges (must match training)
    pub r_cut: f64,
    /// artifact name prefix for variants (default "ff_fwd_B")
    pub variant_prefix: String,
    /// state blob holding model parameters
    pub state_blob: String,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            policy: BatchPolicy::default(),
            n_workers: 2,
            r_cut: 4.0,
            variant_prefix: "ff_fwd_B".to_string(),
            state_blob: "ff_state_init".to_string(),
        }
    }
}

/// Pluggable batched inference: one padded batch in, flat `(energy [B],
/// forces [B*N*3])` f32 buffers out.  Implementations must be pure per
/// occupied row (padding rows must not change occupied rows' results).
pub trait Backend: Send + Sync {
    /// Run one padded batch through `variant`.
    fn run(
        &self, variant: &Variant, pb: &PaddedBatch, state: &[Tensor],
    ) -> Result<(Vec<f32>, Vec<f32>)>;
}

/// The compiled-artifact backend (PJRT executables).
struct XlaBackend {
    engine: Arc<Engine>,
}

impl Backend for XlaBackend {
    fn run(
        &self, variant: &Variant, pb: &PaddedBatch, state: &[Tensor],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let exe = self.engine.load(&variant.name)?;
        let mut inputs: Vec<Tensor> = state.to_vec();
        inputs.push(Tensor::F32(pb.pos.clone()));
        inputs.push(Tensor::I32(pb.species.clone()));
        inputs.push(Tensor::I32(pb.edges.clone()));
        inputs.push(Tensor::F32(pb.edge_mask.clone()));
        inputs.push(Tensor::F32(pb.atom_mask.clone()));
        let outputs = exe.run(&inputs)?;
        let energy = outputs[0].as_f32()?.to_vec();
        let forces = outputs[1].as_f32()?.to_vec();
        Ok((energy, forces))
    }
}

/// Native Gaunt-TP backend, in two modes:
///
/// * **Surrogate** (no model): a fixed, untrained but exactly
///   equivariant analytic model.  Per atom i: a feature `h_i = sum_j
///   w(r_ij) Y(r_ij_hat)` over masked edges, then the rotation-invariant
///   atomic energy is the l=0 channel of the **batched Gaunt
///   self-product** `h_i (x) h_i` via one generic
///   [`apply_batch_par`] call over the op resolved through
///   [`PlanCache::op`].  Forces are symmetric pair terms (exact
///   Newton's third law).
/// * **Learned** ([`NativeGauntBackend::with_model`]): the trained
///   [`Model`] — each flushed batch is decoded once and its graphs are
///   sharded across workers by [`energy_forces_batch_par`]
///   (`pool::shard_rows_with`: one model scratch per worker, per-graph
///   inference allocation-free), energies AND analytic forces end to
///   end through the planned Gaunt engine.
pub struct NativeGauntBackend {
    /// feature degree L of the surrogate's per-atom SH features
    pub l: usize,
    /// worker threads for the batched TP (0 = all cores)
    pub threads: usize,
    /// per-species energy offset scale (surrogate mode)
    pub species_scale: f64,
    /// trained model; `None` serves the analytic surrogate
    pub model: Option<Arc<Model>>,
}

impl Default for NativeGauntBackend {
    fn default() -> Self {
        NativeGauntBackend { l: 2, threads: 0, species_scale: 0.1,
                             model: None }
    }
}

impl NativeGauntBackend {
    /// Serve a trained (or freshly initialized) model.
    pub fn with_model(model: Arc<Model>) -> NativeGauntBackend {
        NativeGauntBackend { model: Some(model), ..Default::default() }
    }

    /// The surrogate's op key: the batched Gaunt self-product every
    /// flushed batch runs.
    fn surrogate_key(&self) -> OpKey {
        OpKey::Gaunt {
            l1: self.l,
            l2: self.l,
            l3: self.l,
            method: ConvMethod::Auto,
        }
    }

    /// Pre-build every plan this backend will touch — the native analog
    /// of the XLA path's eager `engine.load()` of every variant.  In
    /// model mode this runs one tiny inference so the shared FFT tables
    /// and Wigner fit caches exist before the first real batch.
    pub fn warm(&self) {
        match &self.model {
            Some(m) => m.warm(),
            None => {
                let _ = PlanCache::global().op(&self.surrogate_key());
            }
        }
    }

    /// Decode a padded batch and run the learned model, graphs sharded
    /// across the worker pool.
    fn run_model(
        &self, model: &Arc<Model>, pb: &PaddedBatch,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let (b, n_atoms, n_edges) = (pb.b, pb.n_atoms, pb.n_edges);
        // decode once per batch: positions, species, masked edge lists
        let mut pos: Vec<Vec<[f64; 3]>> = Vec::with_capacity(b);
        let mut species: Vec<Vec<usize>> = Vec::with_capacity(b);
        let mut edges: Vec<Vec<(usize, usize)>> = Vec::with_capacity(b);
        for g in 0..b {
            // the capacity that matters is each graph's TRUE atom count,
            // not the server's static padding width
            let na = pb.true_atoms[g];
            if na > model.cfg.max_atoms {
                return Err(err!(
                    "graph {g} has {na} atoms, model capacity is {}",
                    model.cfg.max_atoms
                ));
            }
            let mut p = Vec::with_capacity(na);
            let mut sp = Vec::with_capacity(na);
            for a in 0..na {
                let base = (g * n_atoms + a) * 3;
                p.push([
                    pb.pos[base] as f64,
                    pb.pos[base + 1] as f64,
                    pb.pos[base + 2] as f64,
                ]);
                // validate species HERE: the model's own range check is a
                // debug_assert, compiled out of release serving binaries,
                // and an out-of-range id would silently index unrelated
                // parameters (a negative one would wrap and panic)
                let s = pb.species[g * n_atoms + a];
                if s < 0 || s as usize >= model.cfg.n_species {
                    return Err(err!(
                        "graph {g} atom {a}: species {s} outside the \
                         model's 0..{} range",
                        model.cfg.n_species
                    ));
                }
                sp.push(s as usize);
            }
            let mut el = Vec::new();
            for e in 0..n_edges {
                if pb.edge_mask[g * n_edges + e] == 0.0 {
                    continue;
                }
                el.push((
                    pb.edges[(g * n_edges + e) * 2] as usize,
                    pb.edges[(g * n_edges + e) * 2 + 1] as usize,
                ));
            }
            if el.len() > model.cfg.max_edges {
                return Err(err!(
                    "graph {g} has {} edges, model capacity is {}",
                    el.len(), model.cfg.max_edges
                ));
            }
            pos.push(p);
            species.push(sp);
            edges.push(el);
        }
        let graphs: Vec<GraphRef<'_>> = (0..b)
            .map(|g| GraphRef {
                pos: &pos[g],
                species: &species[g],
                edges: &edges[g],
            })
            .collect();
        let rows = energy_forces_batch_par(model, &graphs, self.threads);
        let row_len = batch_row_len(model);
        let mut energy = vec![0.0f32; b];
        let mut forces = vec![0.0f32; b * n_atoms * 3];
        for g in 0..b {
            energy[g] = rows[g * row_len] as f32;
            for a in 0..pos[g].len() {
                for ax in 0..3 {
                    forces[(g * n_atoms + a) * 3 + ax] =
                        rows[g * row_len + 1 + 3 * a + ax] as f32;
                }
            }
        }
        Ok((energy, forces))
    }
}

impl Backend for NativeGauntBackend {
    fn run(
        &self, _variant: &Variant, pb: &PaddedBatch, _state: &[Tensor],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        if pb.dropped_edges > 0 {
            // shared guard: a one-directional drop would break Newton's
            // third law in both modes
            return Err(err!(
                "native backend: {} edges exceeded the {}-slot budget; \
                 refusing to serve a truncated (asymmetric) edge list",
                pb.dropped_edges, pb.n_edges
            ));
        }
        if let Some(model) = &self.model {
            return self.run_model(model, pb);
        }
        self.run_surrogate(pb)
    }
}

impl NativeGauntBackend {
    /// The untrained analytic surrogate (the pre-model serving path).
    fn run_surrogate(&self, pb: &PaddedBatch) -> Result<(Vec<f32>, Vec<f32>)> {
        let n_feat = num_coeffs(self.l);
        // resolve through the uniform op entry point: the surrogate does
        // not care which plan family evaluates its self-product
        let op = PlanCache::global().op(&self.surrogate_key());
        let (b, n_atoms, n_edges) = (pb.b, pb.n_atoms, pb.n_edges);
        // decode the masked edge list once: (graph, i, j, displacement, r^2)
        let mut edges: Vec<(usize, usize, usize, [f64; 3], f64)> = Vec::new();
        for g in 0..b {
            for e in 0..n_edges {
                if pb.edge_mask[g * n_edges + e] == 0.0 {
                    continue;
                }
                let i = pb.edges[(g * n_edges + e) * 2] as usize;
                let j = pb.edges[(g * n_edges + e) * 2 + 1] as usize;
                let bi = (g * n_atoms + i) * 3;
                let bj = (g * n_atoms + j) * 3;
                let d = [
                    (pb.pos[bi] - pb.pos[bj]) as f64,
                    (pb.pos[bi + 1] - pb.pos[bj + 1]) as f64,
                    (pb.pos[bi + 2] - pb.pos[bj + 2]) as f64,
                ];
                let r2 = d[0] * d[0] + d[1] * d[1] + d[2] * d[2];
                edges.push((g, i, j, d, r2));
            }
        }
        // 1. per-atom SH features accumulated over the edge list
        let mut feats = vec![0.0f64; b * n_atoms * n_feat];
        for &(g, i, _j, d, r2) in &edges {
            let w = 1.0 / (1.0 + r2);
            let y = real_sh_all_xyz(self.l, d);
            let row = &mut feats
                [(g * n_atoms + i) * n_feat..(g * n_atoms + i + 1) * n_feat];
            for (rv, yv) in row.iter_mut().zip(&y) {
                *rv += w * yv;
            }
        }
        // 2. one multi-threaded batched Gaunt self-TP over all atom rows
        //    through the generic op driver (zero padding rows stay zero)
        let rows = b * n_atoms;
        let tp = apply_batch_par(
            op.as_ref(), &BatchInputs::pair(&feats, &feats), rows,
            self.threads,
        );
        // 3. invariant atomic energies -> per-graph energy
        let mut e_atom = vec![0.0f64; rows];
        let mut energy = vec![0.0f32; b];
        for g in 0..b {
            let mut acc = 0.0f64;
            for a in 0..n_atoms {
                if pb.atom_mask[g * n_atoms + a] == 0.0 {
                    continue;
                }
                let e = tp[(g * n_atoms + a) * n_feat];
                e_atom[g * n_atoms + a] = e;
                let s = pb.species[g * n_atoms + a] as f64;
                acc += self.species_scale * (s + 1.0) + e;
            }
            energy[g] = acc as f32;
        }
        // 4. equivariant pair forces from the same decoded edge list
        let mut forces = vec![0.0f32; b * n_atoms * 3];
        for &(g, i, j, d, r2) in &edges {
            let r = r2.sqrt().max(1e-12);
            let c = 1.0 / (1.0 + r2);
            // symmetric scalar x antisymmetric direction => Newton's
            // third law holds exactly for the directed edge pair
            let s_pair = 1.0
                + e_atom[g * n_atoms + i]
                + e_atom[g * n_atoms + j];
            let bi = (g * n_atoms + i) * 3;
            for k in 0..3 {
                forces[bi + k] += (c * s_pair * d[k] / r) as f32;
            }
        }
        Ok((energy, forces))
    }
}

/// Everything [`ForceFieldServer::start_with`] needs besides the batch
/// policy: the backend, its routing variants, the (possibly empty)
/// state tensors, and the static padding shape.  Built by
/// [`BackendSpec::xla`] / [`BackendSpec::native`]; custom backends can
/// construct one directly.
pub struct BackendSpec {
    pub backend: Arc<dyn Backend>,
    pub variants: Vec<Variant>,
    /// model + optimizer state tensors, in artifact input order
    pub state: Vec<Tensor>,
    /// static atom-padding width of every batch
    pub n_atoms: usize,
    /// static edge-slot budget of every batch
    pub n_edges: usize,
}

impl BackendSpec {
    /// Discover `ff_fwd_B*` variants in the manifest, eagerly compile
    /// them, and load the state blob — the compiled-artifact spec.
    pub fn xla(engine: Arc<Engine>, cfg: &ServerConfig) -> Result<BackendSpec> {
        let mut variants = Vec::new();
        let mut n_atoms = 0usize;
        let mut n_edges = 0usize;
        for name in engine.artifact_names() {
            if let Some(rest) = name.strip_prefix(&cfg.variant_prefix) {
                if let Ok(b) = rest.parse::<usize>() {
                    let meta = engine.artifact_meta(&name).cloned()
                        .unwrap_or(Json::Null);
                    n_atoms = meta.get("n_atoms").and_then(Json::as_usize)
                        .unwrap_or(32);
                    n_edges = meta.get("n_edges").and_then(Json::as_usize)
                        .unwrap_or(128);
                    variants.push(Variant { name: name.clone(), batch: b });
                }
            }
        }
        if variants.is_empty() {
            return Err(err!(
                "no '{}*' artifacts found (run `make artifacts`)",
                cfg.variant_prefix
            ));
        }
        // eagerly compile all variants (cold-start off the request path)
        for v in &variants {
            engine.load(&v.name)?;
        }
        let state: Vec<Tensor> = engine
            .load_state_blob(&cfg.state_blob)?
            .into_iter()
            .map(|(_, t)| t)
            .collect();
        Ok(BackendSpec {
            backend: Arc::new(XlaBackend { engine }),
            variants,
            state,
            n_atoms,
            n_edges,
        })
    }

    /// The native Gaunt-TP spec: fixed routing variants, no state
    /// tensors, plans warmed before the first batch.  Mutates
    /// `cfg.r_cut` to the model's training cutoff when a model is
    /// attached (a mismatch would silently drop — or add zero-weight —
    /// edges, so `ServerConfig::default()` stays always-correct).
    pub fn native(
        backend: NativeGauntBackend, cfg: &mut ServerConfig,
    ) -> BackendSpec {
        let variants = vec![
            Variant { name: "native_B1".to_string(), batch: 1 },
            Variant { name: "native_B4".to_string(), batch: 4 },
            Variant { name: "native_B8".to_string(), batch: 8 },
        ];
        if let Some(m) = &backend.model {
            cfg.r_cut = m.cfg.r_cut;
        }
        // cold-start off the request path, like the XLA variants' eager
        // compile: build the plans (tables + FFT workspaces) before the
        // first batch is flushed
        backend.warm();
        // 256 edge slots: a fully connected 16-atom structure fits with no
        // truncation, keeping the directed edge list exactly symmetric
        BackendSpec {
            backend: Arc::new(backend),
            variants,
            state: Vec::new(),
            n_atoms: 32,
            n_edges: 256,
        }
    }
}

struct Shared {
    backend: Arc<dyn Backend>,
    router: Router,
    /// model + optimizer state tensors, in artifact input order
    state: RwLock<Arc<Vec<Tensor>>>,
    metrics: Metrics,
    n_atoms: usize,
    n_edges: usize,
    r_cut: f64,
}

/// The serving coordinator.
pub struct ForceFieldServer {
    batcher: Arc<Batcher>,
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
}

impl ForceFieldServer {
    /// Compiled-artifact entry point: builds [`BackendSpec::xla`] and
    /// hands it to the one constructor, [`ForceFieldServer::start_with`].
    pub fn start(engine: Arc<Engine>, cfg: ServerConfig) -> Result<Self> {
        let spec = BackendSpec::xla(engine, &cfg)?;
        Self::start_with(spec, cfg)
    }

    /// Native entry point: builds [`BackendSpec::native`] (which warms
    /// the plans and syncs `r_cut` to an attached model) and hands it to
    /// [`ForceFieldServer::start_with`].
    pub fn start_native(
        backend: NativeGauntBackend, mut cfg: ServerConfig,
    ) -> Result<Self> {
        let spec = BackendSpec::native(backend, &mut cfg);
        Self::start_with(spec, cfg)
    }

    /// THE server constructor: every start path funnels here.  Spawns
    /// the worker pool over the batcher and routes each flushed batch
    /// through the spec's backend.
    pub fn start_with(spec: BackendSpec, cfg: ServerConfig) -> Result<Self> {
        let shared = Arc::new(Shared {
            backend: spec.backend,
            router: Router::new(spec.variants),
            state: RwLock::new(Arc::new(spec.state)),
            metrics: Metrics::new(),
            n_atoms: spec.n_atoms,
            n_edges: spec.n_edges,
            r_cut: cfg.r_cut,
        });
        let batcher = Arc::new(Batcher::new(cfg.policy));
        let mut workers = Vec::new();
        for w in 0..cfg.n_workers.max(1) {
            let b = batcher.clone();
            let s = shared.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("ff-worker-{w}"))
                    .spawn(move || worker_loop(&b, &s))
                    .expect("spawn worker"),
            );
        }
        Ok(ForceFieldServer {
            batcher,
            shared,
            workers,
            next_id: AtomicU64::new(1),
        })
    }

    /// Replace the model state (e.g. after training).  Takes the full
    /// state tensor list in artifact order.
    pub fn set_state(&self, state: Vec<Tensor>) {
        *self.shared.state.write().unwrap() = Arc::new(state);
    }

    /// Submit asynchronously; the receiver yields the response.
    ///
    /// Structures larger than the server's static atom capacity are
    /// rejected here — padding would otherwise silently truncate them.
    pub fn submit(
        &self,
        pos: Vec<[f64; 3]>,
        species: Vec<usize>,
    ) -> Result<Receiver<Result<ForceResponse, String>>> {
        if pos.len() > self.shared.n_atoms {
            self.shared.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(err!(
                "structure has {} atoms, server capacity is {} \
                 (see max_atoms())",
                pos.len(),
                self.shared.n_atoms
            ));
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        let env = Envelope {
            req: ForceRequest { id, pos, species },
            reply: tx,
            enqueued: Instant::now(),
        };
        self.shared.metrics.requests.fetch_add(1, Ordering::Relaxed);
        self.batcher.push(env).map_err(|_| {
            self.shared.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            err!("queue full (backpressure) or server closed")
        })?;
        Ok(rx)
    }

    /// Submit and wait.
    pub fn infer_blocking(
        &self,
        pos: Vec<[f64; 3]>,
        species: Vec<usize>,
    ) -> Result<ForceResponse> {
        let rx = self.submit(pos, species)?;
        rx.recv()
            .map_err(|e| err!("server dropped request: {e}"))?
            .map_err(|e| err!("{e}"))
    }

    pub fn metrics(&self) -> &Metrics {
        &self.shared.metrics
    }

    /// Snapshot of the global plan cache (builds/hits/len + per-[`OpKey`]
    /// hit counts) — the same numbers folded into [`Metrics::report`]
    /// after every batch, with the per-key breakdown.
    pub fn plan_stats(&self) -> CacheStats {
        PlanCache::global().stats()
    }

    pub fn max_atoms(&self) -> usize {
        self.shared.n_atoms
    }

    /// Drain and stop the workers.
    pub fn shutdown(self) {
        self.batcher.close();
        for w in self.workers {
            let _ = w.join();
        }
    }
}

fn worker_loop(batcher: &Batcher, s: &Shared) {
    while let Some(batch) = batcher.next_batch() {
        // route: split the flushed batch into variant-sized chunks
        let plan = s.router.plan(batch.len());
        let mut offset = 0usize;
        for (variant, k) in plan {
            let chunk = &batch[offset..offset + k];
            offset += k;
            run_chunk(s, variant, chunk);
        }
    }
}

fn run_chunk(s: &Shared, variant: &Variant, chunk: &[Envelope]) {
    let t_exec = Instant::now();
    let result = execute_chunk(s, variant, chunk);
    let exec_ns = t_exec.elapsed().as_nanos() as u64;
    s.metrics.exec_latency.record_ns(exec_ns);
    // fold the plan-cache counters into the serving metrics so report()
    // shows plan churn next to latency (cheap: three atomic loads)
    let cache = PlanCache::global();
    s.metrics.observe_plans(
        cache.builds() as u64,
        cache.hits() as u64,
        cache.len() as u64,
    );
    s.metrics.batches.fetch_add(1, Ordering::Relaxed);
    s.metrics
        .batched_requests
        .fetch_add(chunk.len() as u64, Ordering::Relaxed);
    s.metrics
        .padding_waste
        .fetch_add((variant.batch - chunk.len()) as u64, Ordering::Relaxed);
    match result {
        Ok(responses) => {
            for (env, mut resp) in chunk.iter().zip(responses) {
                let lat = env.enqueued.elapsed();
                resp.latency_s = lat.as_secs_f64();
                s.metrics.latency.record_ns(lat.as_nanos() as u64);
                s.metrics.responses.fetch_add(1, Ordering::Relaxed);
                let _ = env.reply.send(Ok(resp));
            }
        }
        Err(e) => {
            let msg = format!("execution failed: {e}");
            for env in chunk {
                let _ = env.reply.send(Err(msg.clone()));
            }
        }
    }
}

fn execute_chunk(
    s: &Shared,
    variant: &Variant,
    chunk: &[Envelope],
) -> Result<Vec<ForceResponse>> {
    // build graphs (no labels at serving time)
    let graphs: Vec<Graph> = chunk
        .iter()
        .map(|env| Graph {
            pos: env.req.pos.clone(),
            species: env.req.species.clone(),
            energy: 0.0,
            forces: vec![[0.0; 3]; env.req.pos.len()],
        })
        .collect();
    let pb = PaddedBatch::from_graphs(
        &graphs, variant.batch, s.n_atoms, s.n_edges, s.r_cut,
    );
    let state = s.state.read().unwrap().clone();
    let (energy, forces) = s.backend.run(variant, &pb, state.as_ref())?;
    let mut responses = Vec::with_capacity(chunk.len());
    for (g_idx, env) in chunk.iter().enumerate() {
        let na = pb.true_atoms[g_idx];
        let mut f = Vec::with_capacity(na);
        for a in 0..na {
            let base = (g_idx * s.n_atoms + a) * 3;
            f.push([
                forces[base] as f64,
                forces[base + 1] as f64,
                forces[base + 2] as f64,
            ]);
        }
        responses.push(ForceResponse {
            id: env.req.id,
            energy: energy[g_idx] as f64,
            forces: f,
            latency_s: 0.0,
        });
    }
    Ok(responses)
}
