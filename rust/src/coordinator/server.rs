//! Backends and the legacy server façade.
//!
//! Inference is pluggable through [`Backend`]: one padded batch in,
//! flat energy/force buffers out, with the executing model resolved
//! per batch by the service (hot swap happens between batches, never
//! inside one).  [`XlaBackend`] runs compiled PJRT artifacts;
//! [`NativeGauntBackend`] serves either a learned [`Model`] or an
//! analytic equivariant surrogate entirely on the native O(L^3) Gaunt
//! pipeline.
//!
//! The serving engine itself lives in
//! [`crate::coordinator::service::Service`] (typed multi-task protocol,
//! shape-bucketed batching, model registry).  [`ForceFieldServer`] —
//! `start` / `start_native` / `start_with` — remains as a thin
//! compatibility wrapper over `Service::builder()` so existing callers
//! migrate mechanically: `submit` now returns a typed
//! [`Ticket`](crate::coordinator::request::Ticket) (call `.wait()`
//! where you called `.recv().unwrap()`), and `infer_blocking` is
//! unchanged.

use std::sync::Arc;

use super::batcher::{BatchPolicy, BucketConfig};
use super::metrics::Metrics;
use super::registry::Registry;
use super::request::{EnergyForces, ForceResponse, Request, Structure, Ticket};
use super::router::Variant;
use super::service::{AdmissionConfig, Client, Service, SupervisorConfig};
use crate::data::PaddedBatch;
use crate::err;
use crate::model::{batch_row_len, energy_forces_batch_par, GraphRef, Model};
use crate::num_coeffs;
use crate::runtime::{Engine, Tensor};
use crate::so3::sh::real_sh_all_xyz;
use crate::tp::engine::{CacheStats, OpKey, PlanCache, Precision};
use crate::tp::op::{apply_batch_par, BatchInputs};
use crate::tp::ConvMethod;
use crate::util::error::Result;
use crate::util::failpoint;
use crate::util::json::Json;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// default flush policy (per-bucket policies can override via
    /// `buckets`)
    pub policy: BatchPolicy,
    pub n_workers: usize,
    /// neighbor cutoff used to build edges (must match training; when a
    /// model endpoint is resolved its own `r_cut` wins)
    pub r_cut: f64,
    /// artifact name prefix for variants (default "ff_fwd_B")
    pub variant_prefix: String,
    /// state blob holding model parameters
    pub state_blob: String,
    /// explicit shape buckets; `None` = defaults derived from the
    /// backend spec (single fixed bucket for compiled artifacts,
    /// width-halving ladder for the native backend)
    pub buckets: Option<Vec<BucketConfig>>,
    /// serving arithmetic precision for the native Gaunt pipeline:
    /// `F64` (default, bit-identical to training) or `F32` (single
    /// precision interior; tolerances documented in DESIGN.md §11).
    /// Compiled-artifact backends bake their own precision and ignore
    /// this.
    pub precision: Precision,
    /// worker supervision: heartbeat cadence, hang detection, and
    /// bounded respawn backoff (see DESIGN.md §12)
    pub supervisor: SupervisorConfig,
    /// admission control: queue-depth watermarks and shed behavior
    pub admission: AdmissionConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            policy: BatchPolicy::default(),
            n_workers: 2,
            r_cut: 4.0,
            variant_prefix: "ff_fwd_B".to_string(),
            state_blob: "ff_state_init".to_string(),
            buckets: None,
            precision: Precision::F64,
            supervisor: SupervisorConfig::default(),
            admission: AdmissionConfig::default(),
        }
    }
}

/// Pluggable batched inference: one padded batch in, flat `(energy [B],
/// forces [B*N*3])` f32 buffers out.  Implementations must be pure per
/// occupied row (padding rows must not change occupied rows' results).
/// `model` is the registry-resolved model for this batch (`None` for
/// artifact state or the native surrogate); the service resolves it
/// once per batch, which is what makes hot swap tear-free.
pub trait Backend: Send + Sync {
    /// Run one padded batch through `variant`.
    fn run(
        &self, variant: &Variant, pb: &PaddedBatch, state: &[Tensor],
        model: Option<&Arc<Model>>,
    ) -> Result<(Vec<f32>, Vec<f32>)>;
}

/// The compiled-artifact backend (PJRT executables).
struct XlaBackend {
    engine: Arc<Engine>,
}

impl Backend for XlaBackend {
    fn run(
        &self, variant: &Variant, pb: &PaddedBatch, state: &[Tensor],
        _model: Option<&Arc<Model>>,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let exe = self.engine.load(&variant.name)?;
        let mut inputs: Vec<Tensor> = state.to_vec();
        inputs.push(Tensor::F32(pb.pos.clone()));
        inputs.push(Tensor::I32(pb.species.clone()));
        inputs.push(Tensor::I32(pb.edges.clone()));
        inputs.push(Tensor::F32(pb.edge_mask.clone()));
        inputs.push(Tensor::F32(pb.atom_mask.clone()));
        let outputs = exe.run(&inputs)?;
        let energy = outputs[0].as_f32()?.to_vec();
        let forces = outputs[1].as_f32()?.to_vec();
        Ok((energy, forces))
    }
}

/// Native Gaunt-TP backend, in two modes:
///
/// * **Surrogate** (no model resolved): a fixed, untrained but exactly
///   equivariant analytic model.  Per atom i: a feature `h_i = sum_j
///   w(r_ij) Y(r_ij_hat)` over masked edges, then the rotation-invariant
///   atomic energy is the l=0 channel of the **batched Gaunt
///   self-product** `h_i (x) h_i` via one generic
///   [`apply_batch_par`] call over the op resolved through
///   [`PlanCache::op`].  Forces are symmetric pair terms (exact
///   Newton's third law).
/// * **Learned**: the resolved [`Model`] — each flushed batch is
///   decoded once and its graphs are sharded across workers by
///   [`energy_forces_batch_par`] (`pool::shard_rows_with`: one model
///   scratch per worker, per-graph inference allocation-free), energies
///   AND analytic forces end to end through the planned Gaunt engine.
///   The per-batch model normally arrives from the service registry
///   (hot-swappable); `self.model` remains as the fixed fallback for
///   directly-constructed specs.
pub struct NativeGauntBackend {
    /// feature degree L of the surrogate's per-atom SH features
    pub l: usize,
    /// worker threads for the batched TP (0 = all cores)
    pub threads: usize,
    /// per-species energy offset scale (surrogate mode)
    pub species_scale: f64,
    /// fixed model; `None` serves the registry model or the analytic
    /// surrogate.  `Service::builder()` moves this into the registry's
    /// default endpoint so it becomes hot-swappable.
    pub model: Option<Arc<Model>>,
    /// arithmetic precision of the surrogate's Gaunt self-product
    /// (train f64, optionally serve f32); learned-model inference is
    /// f64 regardless.
    pub precision: Precision,
}

impl Default for NativeGauntBackend {
    fn default() -> Self {
        NativeGauntBackend { l: 2, threads: 0, species_scale: 0.1,
                             model: None, precision: Precision::F64 }
    }
}

impl NativeGauntBackend {
    /// Serve a trained (or freshly initialized) model.
    pub fn with_model(model: Arc<Model>) -> NativeGauntBackend {
        NativeGauntBackend { model: Some(model), ..Default::default() }
    }

    /// The surrogate's op key: the batched Gaunt self-product every
    /// flushed batch runs, lowered to the configured serving precision
    /// (`F32` re-keys to [`OpKey::GauntF32`]).
    fn surrogate_key(&self) -> OpKey {
        OpKey::Gaunt {
            l1: self.l,
            l2: self.l,
            l3: self.l,
            method: ConvMethod::Auto,
        }
        .with_precision(self.precision)
    }

    /// Pre-build every plan this backend will touch — the native analog
    /// of the XLA path's eager `engine.load()` of every variant.  In
    /// model mode this runs one tiny inference so the shared FFT tables
    /// and Wigner fit caches exist before the first real batch.
    pub fn warm(&self) {
        match &self.model {
            Some(m) => m.warm(),
            None => {
                let _ = PlanCache::global().op(&self.surrogate_key());
            }
        }
    }

    /// Decode a padded batch and run the learned model, graphs sharded
    /// across the worker pool.
    fn run_model(
        &self, model: &Arc<Model>, pb: &PaddedBatch,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let (b, n_atoms, n_edges) = (pb.b, pb.n_atoms, pb.n_edges);
        // decode once per batch: positions, species, masked edge lists
        let mut pos: Vec<Vec<[f64; 3]>> = Vec::with_capacity(b);
        let mut species: Vec<Vec<usize>> = Vec::with_capacity(b);
        let mut edges: Vec<Vec<(usize, usize)>> = Vec::with_capacity(b);
        for g in 0..b {
            // the capacity that matters is each graph's TRUE atom count,
            // not the server's static padding width
            let na = pb.true_atoms[g];
            if na > model.cfg.max_atoms {
                return Err(err!(
                    "graph {g} has {na} atoms, model capacity is {}",
                    model.cfg.max_atoms
                ));
            }
            let mut p = Vec::with_capacity(na);
            let mut sp = Vec::with_capacity(na);
            for a in 0..na {
                let base = (g * n_atoms + a) * 3;
                p.push([
                    pb.pos[base] as f64,
                    pb.pos[base + 1] as f64,
                    pb.pos[base + 2] as f64,
                ]);
                // validate species HERE: the model's own range check is a
                // debug_assert, compiled out of release serving binaries,
                // and an out-of-range id would silently index unrelated
                // parameters (a negative one would wrap and panic)
                let s = pb.species[g * n_atoms + a];
                if s < 0 || s as usize >= model.cfg.n_species {
                    return Err(err!(
                        "graph {g} atom {a}: species {s} outside the \
                         model's 0..{} range",
                        model.cfg.n_species
                    ));
                }
                sp.push(s as usize);
            }
            let mut el = Vec::new();
            for e in 0..n_edges {
                if pb.edge_mask[g * n_edges + e] == 0.0 {
                    continue;
                }
                el.push((
                    pb.edges[(g * n_edges + e) * 2] as usize,
                    pb.edges[(g * n_edges + e) * 2 + 1] as usize,
                ));
            }
            if el.len() > model.cfg.max_edges {
                return Err(err!(
                    "graph {g} has {} edges, model capacity is {}",
                    el.len(), model.cfg.max_edges
                ));
            }
            pos.push(p);
            species.push(sp);
            edges.push(el);
        }
        let graphs: Vec<GraphRef<'_>> = (0..b)
            .map(|g| GraphRef {
                pos: &pos[g],
                species: &species[g],
                edges: &edges[g],
                shifts: None,
            })
            .collect();
        let rows = energy_forces_batch_par(model, &graphs, self.threads);
        let row_len = batch_row_len(model);
        let mut energy = vec![0.0f32; b];
        let mut forces = vec![0.0f32; b * n_atoms * 3];
        for g in 0..b {
            energy[g] = rows[g * row_len] as f32;
            for a in 0..pos[g].len() {
                for ax in 0..3 {
                    forces[(g * n_atoms + a) * 3 + ax] =
                        rows[g * row_len + 1 + 3 * a + ax] as f32;
                }
            }
        }
        Ok((energy, forces))
    }
}

impl Backend for NativeGauntBackend {
    fn run(
        &self, _variant: &Variant, pb: &PaddedBatch, _state: &[Tensor],
        model: Option<&Arc<Model>>,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        if pb.dropped_edges > 0 {
            // shared guard: a one-directional drop would break Newton's
            // third law in both modes
            return Err(err!(
                "native backend: {} edges exceeded the {}-slot budget; \
                 refusing to serve a truncated (asymmetric) edge list",
                pb.dropped_edges, pb.n_edges
            ));
        }
        // chaos site: `error` fails the whole batch (typed Exec error at
        // the service boundary), `nan` poisons row 0's energy so the
        // worker's ExecGuard quarantines exactly that row, `delay`
        // stretches execution for hang detection
        let fault = failpoint::check("backend.run");
        if let Some(failpoint::Fault::Error(m)) = fault {
            return Err(err!("{m}"));
        }
        // the per-batch registry resolution wins over the fixed model
        let mut out = if let Some(m) = model.or(self.model.as_ref()) {
            self.run_model(m, pb)?
        } else {
            self.run_surrogate(pb)?
        };
        if matches!(fault, Some(failpoint::Fault::Nan)) {
            if let Some(e) = out.0.first_mut() {
                *e = f32::NAN;
            }
        }
        Ok(out)
    }
}

impl NativeGauntBackend {
    /// The untrained analytic surrogate (the pre-model serving path).
    fn run_surrogate(&self, pb: &PaddedBatch) -> Result<(Vec<f32>, Vec<f32>)> {
        let n_feat = num_coeffs(self.l);
        // resolve through the uniform op entry point: the surrogate does
        // not care which plan family evaluates its self-product
        let op = PlanCache::global().op(&self.surrogate_key());
        let (b, n_atoms, n_edges) = (pb.b, pb.n_atoms, pb.n_edges);
        // decode the masked edge list once: (graph, i, j, displacement, r^2)
        let mut edges: Vec<(usize, usize, usize, [f64; 3], f64)> = Vec::new();
        for g in 0..b {
            for e in 0..n_edges {
                if pb.edge_mask[g * n_edges + e] == 0.0 {
                    continue;
                }
                let i = pb.edges[(g * n_edges + e) * 2] as usize;
                let j = pb.edges[(g * n_edges + e) * 2 + 1] as usize;
                let bi = (g * n_atoms + i) * 3;
                let bj = (g * n_atoms + j) * 3;
                let d = [
                    (pb.pos[bi] - pb.pos[bj]) as f64,
                    (pb.pos[bi + 1] - pb.pos[bj + 1]) as f64,
                    (pb.pos[bi + 2] - pb.pos[bj + 2]) as f64,
                ];
                let r2 = d[0] * d[0] + d[1] * d[1] + d[2] * d[2];
                edges.push((g, i, j, d, r2));
            }
        }
        // 1. per-atom SH features accumulated over the edge list
        let mut feats = vec![0.0f64; b * n_atoms * n_feat];
        for &(g, i, _j, d, r2) in &edges {
            let w = 1.0 / (1.0 + r2);
            let y = real_sh_all_xyz(self.l, d);
            let row = &mut feats
                [(g * n_atoms + i) * n_feat..(g * n_atoms + i + 1) * n_feat];
            for (rv, yv) in row.iter_mut().zip(&y) {
                *rv += w * yv;
            }
        }
        // 2. one multi-threaded batched Gaunt self-TP over all atom rows
        //    through the generic op driver (zero padding rows stay zero)
        let rows = b * n_atoms;
        let tp = apply_batch_par(
            op.as_ref(), &BatchInputs::pair(&feats, &feats), rows,
            self.threads,
        );
        // 3. invariant atomic energies -> per-graph energy
        let mut e_atom = vec![0.0f64; rows];
        let mut energy = vec![0.0f32; b];
        for g in 0..b {
            let mut acc = 0.0f64;
            for a in 0..n_atoms {
                if pb.atom_mask[g * n_atoms + a] == 0.0 {
                    continue;
                }
                let e = tp[(g * n_atoms + a) * n_feat];
                e_atom[g * n_atoms + a] = e;
                let s = pb.species[g * n_atoms + a] as f64;
                acc += self.species_scale * (s + 1.0) + e;
            }
            energy[g] = acc as f32;
        }
        // 4. equivariant pair forces from the same decoded edge list
        let mut forces = vec![0.0f32; b * n_atoms * 3];
        for &(g, i, j, d, r2) in &edges {
            let r = r2.sqrt().max(1e-12);
            let c = 1.0 / (1.0 + r2);
            // symmetric scalar x antisymmetric direction => Newton's
            // third law holds exactly for the directed edge pair
            let s_pair = 1.0
                + e_atom[g * n_atoms + i]
                + e_atom[g * n_atoms + j];
            let bi = (g * n_atoms + i) * 3;
            for k in 0..3 {
                forces[bi + k] += (c * s_pair * d[k] / r) as f32;
            }
        }
        Ok((energy, forces))
    }
}

/// Everything `Service::builder()` needs besides the batch policy: the
/// backend, its routing variants, the (possibly empty) state tensors,
/// and the shape capacity.  Built by [`BackendSpec::xla`] /
/// [`BackendSpec::native`]; custom backends can construct one directly.
pub struct BackendSpec {
    pub backend: Arc<dyn Backend>,
    pub variants: Vec<Variant>,
    /// model + optimizer state tensors, in artifact input order
    pub state: Vec<Tensor>,
    /// atom capacity (the largest bucket width)
    pub n_atoms: usize,
    /// edge-slot budget at full width
    pub n_edges: usize,
    /// compiled artifacts bake their padding shape in: a fixed-shape
    /// spec is served from ONE bucket of exactly (n_atoms, n_edges);
    /// native backends accept any bucket ladder
    pub fixed_shape: bool,
    /// arithmetic precision this spec serves at (surfaced in metrics /
    /// introspection; compiled artifacts report `F64`)
    pub precision: Precision,
}

impl BackendSpec {
    /// Discover `ff_fwd_B*` variants in the manifest, eagerly compile
    /// them, and load the state blob — the compiled-artifact spec.
    pub fn xla(engine: Arc<Engine>, cfg: &ServerConfig) -> Result<BackendSpec> {
        let mut variants = Vec::new();
        let mut n_atoms = 0usize;
        let mut n_edges = 0usize;
        for name in engine.artifact_names() {
            if let Some(rest) = name.strip_prefix(&cfg.variant_prefix) {
                if let Ok(b) = rest.parse::<usize>() {
                    let meta = engine.artifact_meta(&name).cloned()
                        .unwrap_or(Json::Null);
                    n_atoms = meta.get("n_atoms").and_then(Json::as_usize)
                        .unwrap_or(32);
                    n_edges = meta.get("n_edges").and_then(Json::as_usize)
                        .unwrap_or(128);
                    variants.push(Variant { name: name.clone(), batch: b });
                }
            }
        }
        if variants.is_empty() {
            return Err(err!(
                "no '{}*' artifacts found (run `make artifacts`)",
                cfg.variant_prefix
            ));
        }
        // eagerly compile all variants (cold-start off the request path)
        for v in &variants {
            engine.load(&v.name)?;
        }
        let state: Vec<Tensor> = engine
            .load_state_blob(&cfg.state_blob)?
            .into_iter()
            .map(|(_, t)| t)
            .collect();
        Ok(BackendSpec {
            backend: Arc::new(XlaBackend { engine }),
            variants,
            state,
            n_atoms,
            n_edges,
            fixed_shape: true,
            precision: Precision::F64,
        })
    }

    /// The native Gaunt-TP spec: fixed routing variants, no state
    /// tensors, plans warmed before the first batch.  Mutates
    /// `cfg.r_cut` to the model's training cutoff when a model is
    /// attached (a mismatch would silently drop — or add zero-weight —
    /// edges, so `ServerConfig::default()` stays always-correct).
    pub fn native(
        mut backend: NativeGauntBackend, cfg: &mut ServerConfig,
    ) -> BackendSpec {
        let variants = vec![
            Variant { name: "native_B1".to_string(), batch: 1 },
            Variant { name: "native_B4".to_string(), batch: 4 },
            Variant { name: "native_B8".to_string(), batch: 8 },
        ];
        if let Some(m) = &backend.model {
            cfg.r_cut = m.cfg.r_cut;
        }
        // the config's serving precision wins over whatever the backend
        // was constructed with, so `ServiceBuilder::precision` is the
        // one knob
        backend.precision = cfg.precision;
        let precision = backend.precision;
        // cold-start off the request path, like the XLA variants' eager
        // compile: build the plans (tables + FFT workspaces) before the
        // first batch is flushed
        backend.warm();
        // 256 edge slots: a fully connected 16-atom structure fits with no
        // truncation, keeping the directed edge list exactly symmetric
        BackendSpec {
            backend: Arc::new(backend),
            variants,
            state: Vec::new(),
            n_atoms: 32,
            n_edges: 256,
            fixed_shape: false,
            precision,
        }
    }
}

/// The legacy serving façade: a thin compatibility wrapper over
/// [`Service`] keeping the historical constructor and call shapes
/// alive.  New code should use `Service::builder()` and the typed task
/// API directly (see DESIGN.md §10).
pub struct ForceFieldServer {
    service: Service,
}

impl ForceFieldServer {
    /// Compiled-artifact entry point: builds [`BackendSpec::xla`] and
    /// hands it to `Service::builder()`.
    pub fn start(engine: Arc<Engine>, cfg: ServerConfig) -> Result<Self> {
        let spec = BackendSpec::xla(engine, &cfg)?;
        Self::start_with(spec, cfg)
    }

    /// Native entry point.  The backend's fixed model (if any) is
    /// promoted into the service registry's default endpoint, so a
    /// server started this way is hot-swappable via
    /// [`ForceFieldServer::promote`].
    pub fn start_native(
        backend: NativeGauntBackend, cfg: ServerConfig,
    ) -> Result<Self> {
        Ok(ForceFieldServer {
            service: Service::builder().native(backend).config(cfg).build()?,
        })
    }

    /// Spec entry point: every start path funnels into
    /// `Service::builder()`.
    pub fn start_with(spec: BackendSpec, cfg: ServerConfig) -> Result<Self> {
        Ok(ForceFieldServer {
            service: Service::builder().backend(spec).config(cfg).build()?,
        })
    }

    /// The underlying typed service.
    pub fn service(&self) -> &Service {
        &self.service
    }

    /// A cheap cloneable handle for the typed task API.
    pub fn client(&self) -> Client {
        self.service.client()
    }

    /// Replace the artifact state tensors (e.g. after training).  Takes
    /// the full state tensor list in artifact order.
    pub fn set_state(&self, state: Vec<Tensor>) {
        self.service.set_state(state);
    }

    /// Hot-swap a model into a named registry endpoint; returns the new
    /// version.  A snapshot with any non-finite parameter is refused
    /// (the previous version keeps serving).
    pub fn promote(&self, name: &str, model: Arc<Model>) -> Result<u64> {
        self.service.promote(name, model)
    }

    /// Submit asynchronously; the returned typed ticket yields the
    /// response via `wait()` / `try_poll()` (the legacy
    /// `rx.recv().unwrap().unwrap()` becomes `ticket.wait().unwrap()`).
    ///
    /// Structures larger than the largest shape bucket are rejected
    /// here — padding would otherwise silently truncate them.
    pub fn submit(
        &self,
        pos: Vec<[f64; 3]>,
        species: Vec<usize>,
    ) -> Result<Ticket<EnergyForces>> {
        self.service
            .client()
            .submit(Request::new(EnergyForces(Structure::new(pos, species))))
            .map_err(|e| err!("{e}"))
    }

    /// Submit and wait.
    pub fn infer_blocking(
        &self,
        pos: Vec<[f64; 3]>,
        species: Vec<usize>,
    ) -> Result<ForceResponse> {
        self.submit(pos, species)?.wait().map_err(|e| err!("{e}"))
    }

    pub fn metrics(&self) -> &Metrics {
        self.service.metrics()
    }

    /// The service's model registry (endpoints + versions).
    pub fn registry(&self) -> &Registry {
        self.service.registry()
    }

    /// Snapshot of the global plan cache (builds/hits/len + per-[`OpKey`]
    /// hit counts) — the same numbers folded into [`Metrics::report`]
    /// after every batch, with the per-key breakdown.
    pub fn plan_stats(&self) -> CacheStats {
        PlanCache::global().stats()
    }

    pub fn max_atoms(&self) -> usize {
        self.service.max_atoms()
    }

    /// Drain and stop the workers (queued requests are failed
    /// deterministically, never leaked).
    pub fn shutdown(self) {
        self.service.shutdown();
    }
}
