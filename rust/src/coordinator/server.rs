//! The force-field serving coordinator: worker pool over the dynamic
//! batcher, routing each flushed batch to the smallest compiled variant.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, RwLock};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::batcher::{BatchPolicy, Batcher};
use super::metrics::Metrics;
use super::request::{Envelope, ForceRequest, ForceResponse};
use super::router::{Router, Variant};
use crate::data::{Graph, PaddedBatch};
use crate::runtime::{Engine, Executable, Tensor};
use crate::util::json::Json;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub policy: BatchPolicy,
    pub n_workers: usize,
    /// neighbor cutoff used to build edges (must match training)
    pub r_cut: f64,
    /// artifact name prefix for variants (default "ff_fwd_B")
    pub variant_prefix: String,
    /// state blob holding model parameters
    pub state_blob: String,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            policy: BatchPolicy::default(),
            n_workers: 2,
            r_cut: 4.0,
            variant_prefix: "ff_fwd_B".to_string(),
            state_blob: "ff_state_init".to_string(),
        }
    }
}

struct Shared {
    engine: Arc<Engine>,
    router: Router,
    /// model + optimizer state tensors, in artifact input order
    state: RwLock<Arc<Vec<Tensor>>>,
    metrics: Metrics,
    n_atoms: usize,
    n_edges: usize,
    r_cut: f64,
}

/// The serving coordinator.
pub struct ForceFieldServer {
    batcher: Arc<Batcher>,
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
}

impl ForceFieldServer {
    /// Discover `ff_fwd_B*` variants in the manifest, load parameters, and
    /// spawn the worker pool.
    pub fn start(engine: Arc<Engine>, cfg: ServerConfig) -> Result<Self> {
        let mut variants = Vec::new();
        let mut n_atoms = 0usize;
        let mut n_edges = 0usize;
        for name in engine.artifact_names() {
            if let Some(rest) = name.strip_prefix(&cfg.variant_prefix) {
                if let Ok(b) = rest.parse::<usize>() {
                    let meta = engine.artifact_meta(&name).cloned()
                        .unwrap_or(Json::Null);
                    n_atoms = meta.get("n_atoms").and_then(Json::as_usize)
                        .unwrap_or(32);
                    n_edges = meta.get("n_edges").and_then(Json::as_usize)
                        .unwrap_or(128);
                    variants.push(Variant { name: name.clone(), batch: b });
                }
            }
        }
        if variants.is_empty() {
            return Err(anyhow!(
                "no '{}*' artifacts found (run `make artifacts`)",
                cfg.variant_prefix
            ));
        }
        // eagerly compile all variants (cold-start off the request path)
        for v in &variants {
            engine.load(&v.name)?;
        }
        let state: Vec<Tensor> = engine
            .load_state_blob(&cfg.state_blob)?
            .into_iter()
            .map(|(_, t)| t)
            .collect();
        let shared = Arc::new(Shared {
            engine: engine.clone(),
            router: Router::new(variants),
            state: RwLock::new(Arc::new(state)),
            metrics: Metrics::new(),
            n_atoms,
            n_edges,
            r_cut: cfg.r_cut,
        });
        let batcher = Arc::new(Batcher::new(cfg.policy));
        let mut workers = Vec::new();
        for w in 0..cfg.n_workers.max(1) {
            let b = batcher.clone();
            let s = shared.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("ff-worker-{w}"))
                    .spawn(move || worker_loop(&b, &s))
                    .expect("spawn worker"),
            );
        }
        Ok(ForceFieldServer {
            batcher,
            shared,
            workers,
            next_id: AtomicU64::new(1),
        })
    }

    /// Replace the model state (e.g. after training).  Takes the full
    /// state tensor list in artifact order.
    pub fn set_state(&self, state: Vec<Tensor>) {
        *self.shared.state.write().unwrap() = Arc::new(state);
    }

    /// Submit asynchronously; the receiver yields the response.
    pub fn submit(
        &self,
        pos: Vec<[f64; 3]>,
        species: Vec<usize>,
    ) -> Result<Receiver<Result<ForceResponse, String>>> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        let env = Envelope {
            req: ForceRequest { id, pos, species },
            reply: tx,
            enqueued: Instant::now(),
        };
        self.shared.metrics.requests.fetch_add(1, Ordering::Relaxed);
        self.batcher.push(env).map_err(|_| {
            self.shared.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            anyhow!("queue full (backpressure) or server closed")
        })?;
        Ok(rx)
    }

    /// Submit and wait.
    pub fn infer_blocking(
        &self,
        pos: Vec<[f64; 3]>,
        species: Vec<usize>,
    ) -> Result<ForceResponse> {
        let rx = self.submit(pos, species)?;
        rx.recv()
            .map_err(|e| anyhow!("server dropped request: {e}"))?
            .map_err(|e| anyhow!(e))
    }

    pub fn metrics(&self) -> &Metrics {
        &self.shared.metrics
    }

    pub fn max_atoms(&self) -> usize {
        self.shared.n_atoms
    }

    /// Drain and stop the workers.
    pub fn shutdown(self) {
        self.batcher.close();
        for w in self.workers {
            let _ = w.join();
        }
    }
}

fn worker_loop(batcher: &Batcher, s: &Shared) {
    while let Some(batch) = batcher.next_batch() {
        // route: split the flushed batch into variant-sized chunks
        let plan = s.router.plan(batch.len());
        let mut offset = 0usize;
        for (variant, k) in plan {
            let chunk = &batch[offset..offset + k];
            offset += k;
            run_chunk(s, variant, chunk);
        }
    }
}

fn run_chunk(s: &Shared, variant: &Variant, chunk: &[Envelope]) {
    let t_exec = Instant::now();
    let result = execute_chunk(s, variant, chunk);
    let exec_ns = t_exec.elapsed().as_nanos() as u64;
    s.metrics.exec_latency.record_ns(exec_ns);
    s.metrics.batches.fetch_add(1, Ordering::Relaxed);
    s.metrics
        .batched_requests
        .fetch_add(chunk.len() as u64, Ordering::Relaxed);
    s.metrics
        .padding_waste
        .fetch_add((variant.batch - chunk.len()) as u64, Ordering::Relaxed);
    match result {
        Ok(responses) => {
            for (env, mut resp) in chunk.iter().zip(responses) {
                let lat = env.enqueued.elapsed();
                resp.latency_s = lat.as_secs_f64();
                s.metrics.latency.record_ns(lat.as_nanos() as u64);
                s.metrics.responses.fetch_add(1, Ordering::Relaxed);
                let _ = env.reply.send(Ok(resp));
            }
        }
        Err(e) => {
            let msg = format!("execution failed: {e}");
            for env in chunk {
                let _ = env.reply.send(Err(msg.clone()));
            }
        }
    }
}

fn execute_chunk(
    s: &Shared,
    variant: &Variant,
    chunk: &[Envelope],
) -> Result<Vec<ForceResponse>> {
    let exe: Arc<Executable> = s.engine.load(&variant.name)?;
    // build graphs (no labels at serving time)
    let graphs: Vec<Graph> = chunk
        .iter()
        .map(|env| Graph {
            pos: env.req.pos.clone(),
            species: env.req.species.clone(),
            energy: 0.0,
            forces: vec![[0.0; 3]; env.req.pos.len()],
        })
        .collect();
    let pb = PaddedBatch::from_graphs(
        &graphs, variant.batch, s.n_atoms, s.n_edges, s.r_cut,
    );
    let state = s.state.read().unwrap().clone();
    let mut inputs: Vec<Tensor> = state.as_ref().clone();
    inputs.push(Tensor::F32(pb.pos.clone()));
    inputs.push(Tensor::I32(pb.species.clone()));
    inputs.push(Tensor::I32(pb.edges.clone()));
    inputs.push(Tensor::F32(pb.edge_mask.clone()));
    inputs.push(Tensor::F32(pb.atom_mask.clone()));
    let outputs = exe.run(&inputs)?;
    let energy = outputs[0].as_f32()?;
    let forces = outputs[1].as_f32()?;
    let mut responses = Vec::with_capacity(chunk.len());
    for (g_idx, env) in chunk.iter().enumerate() {
        let na = pb.true_atoms[g_idx];
        let mut f = Vec::with_capacity(na);
        for a in 0..na {
            let base = (g_idx * s.n_atoms + a) * 3;
            f.push([
                forces[base] as f64,
                forces[base + 1] as f64,
                forces[base + 2] as f64,
            ]);
        }
        responses.push(ForceResponse {
            id: env.req.id,
            energy: energy[g_idx] as f64,
            forces: f,
            latency_s: 0.0,
        });
    }
    Ok(responses)
}
