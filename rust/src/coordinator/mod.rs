//! Layer-3 coordinator: a force-field serving + training system in the
//! vLLM mold (request router, dynamic batcher, worker pool, metrics),
//! built on std threads (tokio is unavailable offline; the event loop is
//! a Condvar-driven queue, see DESIGN.md §3).
//!
//! Dataflow (serving):
//!   client -> [`server::ForceFieldServer::submit`] -> [`batcher`] queue
//!   -> worker thread: [`router`] picks the smallest executable variant
//!   that fits -> pad ([`crate::data::PaddedBatch`]) -> PJRT execute ->
//!   unpad -> respond through the per-request channel.
//!
//! Dataflow (training): [`trainer::Trainer`] drives the fused
//! `ff_train_step_*` artifact over shuffled minibatches, and
//! [`trainer::NativeTrainer`] runs the artifact-free loop over the
//! native Gaunt-engine model (energy + force loss, Adam, JSON
//! checkpoints) whose result feeds straight into
//! [`server::NativeGauntBackend`].

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod router;
pub mod server;
pub mod trainer;

pub use request::{ForceRequest, ForceResponse};
pub use server::{
    Backend, BackendSpec, ForceFieldServer, NativeGauntBackend, ServerConfig,
};
pub use trainer::{NativeTrainConfig, NativeTrainer, Trainer};
