//! Layer-3 coordinator: a force-field serving + training system in the
//! vLLM mold (typed task protocol, shape-bucketed dynamic batching,
//! versioned model registry, worker pool, metrics), built on std
//! threads (tokio is unavailable offline; the event loop is a
//! Condvar-driven queue, see DESIGN.md §3/§10).
//!
//! Dataflow (serving):
//!   client -> [`service::Client::submit`] (`Request<Task>` ->
//!   [`request::Ticket`], reply-on-drop guaranteed) -> per-atom-count
//!   bucket queue ([`batcher::BucketedBatcher`]) -> worker thread:
//!   resolve the model endpoint ONCE per batch ([`registry::Registry`],
//!   hot-swappable) -> [`router`] picks the smallest executable variant
//!   that fits -> pad to the BUCKET width ([`crate::data::PaddedBatch`])
//!   -> backend execute -> unpad -> typed reply.  Relax / MD-rollout
//!   tasks run as long tasks on the worker, streaming frames.
//!
//! Dataflow (training): [`trainer::Trainer`] drives the fused
//! `ff_train_step_*` artifact over shuffled minibatches, and
//! [`trainer::NativeTrainer`] runs the artifact-free loop over the
//! native Gaunt-engine model (energy + force loss, Adam, JSON
//! checkpoints) whose checkpoints can be hot-promoted into a live
//! [`service::Service`] via [`trainer::NativeTrainer::promote_to`].
//!
//! The legacy single-call façade ([`server::ForceFieldServer`],
//! `start`/`start_native`/`start_with`) remains as a thin wrapper over
//! [`service::Service::builder`].

pub mod batcher;
pub mod metrics;
pub mod registry;
pub mod request;
pub mod router;
pub mod server;
pub mod service;
pub mod trainer;

pub use batcher::{BatchPolicy, BucketConfig, PushError};
pub use metrics::MetricsSnapshot;
pub use registry::{ModelVersion, Registry, DEFAULT_ENDPOINT};
pub use request::{
    Batch, EnergyForces, EnergyOnly, EnergyOut, ExecFault, ForceRequest,
    ForceResponse, Frame, MdRollout, RawTicket, Relax, Reply, Request,
    RolloutSummary, ServiceError, Structure, Task, TaskSpec, Ticket,
    Trajectory,
};
pub use server::{
    Backend, BackendSpec, ForceFieldServer, NativeGauntBackend, ServerConfig,
};
pub use service::{
    AdmissionConfig, Client, HealthState, RetryPolicy, Service,
    ServiceBuilder, SupervisorConfig,
};
pub use trainer::{NativeTrainConfig, NativeTrainer, Trainer};
