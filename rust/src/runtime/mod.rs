//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) produced
//! by `python/compile/aot.py`, compiles them once on the CPU PJRT client,
//! and executes them from the serving hot path.
//!
//! Python never runs here — HLO text is the interchange format (see
//! DESIGN.md §2 for why text, not serialized protos).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::util::error::{Context, Result};
use crate::util::json::{parse, Json};
use crate::xla;
use crate::{bail, err};

/// Supported tensor dtypes (all the artifacts use f32/i32).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn from_str(s: &str) -> Result<DType> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => bail!("unsupported dtype {other}"),
        }
    }
}

/// Shape + dtype + name of one executable input/output.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let name = j.get("name").and_then(Json::as_str).unwrap_or("").to_string();
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| err!("missing shape"))?
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        let dtype = DType::from_str(
            j.get("dtype").and_then(Json::as_str).unwrap_or("float32"),
        )?;
        Ok(TensorSpec { name, shape, dtype })
    }
}

/// Host tensor (row-major).
#[derive(Clone, Debug)]
pub enum Tensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Tensor {
    pub fn len(&self) -> usize {
        match self {
            Tensor::F32(v) => v.len(),
            Tensor::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    fn to_literal(&self, spec: &TensorSpec) -> Result<xla::Literal> {
        let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32(v) => xla::Literal::vec1(v),
            Tensor::I32(v) => xla::Literal::vec1(v),
        };
        Ok(lit.reshape(&dims)?)
    }

    fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<Tensor> {
        Ok(match spec.dtype {
            DType::F32 => Tensor::F32(lit.to_vec::<f32>()?),
            DType::I32 => Tensor::I32(lit.to_vec::<i32>()?),
        })
    }
}

/// One compiled artifact.
pub struct Executable {
    pub name: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub meta: Json,
    exe: xla::PjRtLoadedExecutable,
}

// SAFETY: the PJRT CPU client is internally synchronized; the handles are
// reference-counted pointers into the runtime.  We only ever execute
// through &self, and PJRT allows concurrent Execute calls.
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// Validate inputs against the manifest specs and execute.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        if inputs.len() != self.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (t, spec) in inputs.iter().zip(&self.inputs) {
            if t.len() != spec.numel() {
                bail!(
                    "{}: input '{}' expects {} elements (shape {:?}), got {}",
                    self.name, spec.name, spec.numel(), spec.shape, t.len()
                );
            }
            literals.push(t.to_literal(spec)?);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        if parts.len() != self.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {}",
                self.name,
                self.outputs.len(),
                parts.len()
            );
        }
        parts
            .iter()
            .zip(&self.outputs)
            .map(|(l, s)| Tensor::from_literal(l, s))
            .collect()
    }
}

/// The engine: PJRT client + artifact registry + compiled-executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    pub artifacts_dir: PathBuf,
    manifest: Json,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

// SAFETY: see Executable.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    pub fn new<P: AsRef<Path>>(artifacts_dir: P) -> Result<Engine> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!("reading {manifest_path:?} (run `make artifacts`)")
        })?;
        let manifest = parse(&text).map_err(|e| err!("manifest: {e}"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine {
            client,
            artifacts_dir: dir,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Names of all artifacts in the manifest.
    pub fn artifact_names(&self) -> Vec<String> {
        self.manifest
            .get("artifacts")
            .and_then(Json::as_obj)
            .map(|m| m.keys().cloned().collect())
            .unwrap_or_default()
    }

    /// Manifest metadata of one artifact.
    pub fn artifact_meta(&self, name: &str) -> Option<&Json> {
        self.manifest.get("artifacts")?.get(name)?.get("meta")
    }

    /// Load (compile-once, cached) an artifact by name.
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let entry = self
            .manifest
            .get("artifacts")
            .and_then(|a| a.get(name))
            .ok_or_else(|| err!("artifact '{name}' not in manifest"))?;
        let file = entry
            .get("file")
            .and_then(Json::as_str)
            .ok_or_else(|| err!("artifact '{name}' missing file"))?;
        let path = self.artifacts_dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| err!("bad path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let inputs = entry
            .get("inputs")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .map(TensorSpec::from_json)
            .collect::<Result<Vec<_>>>()?;
        let outputs = entry
            .get("outputs")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .map(TensorSpec::from_json)
            .collect::<Result<Vec<_>>>()?;
        let meta = entry.get("meta").cloned().unwrap_or(Json::Null);
        let arc = Arc::new(Executable {
            name: name.to_string(),
            inputs,
            outputs,
            meta,
            exe,
        });
        self.cache.lock().unwrap().insert(name.to_string(), arc.clone());
        Ok(arc)
    }

    /// Load a state blob (e.g. `ff_state_init`): named tensors in manifest
    /// order (these are the flattened params + optimizer state).
    pub fn load_state_blob(&self, name: &str) -> Result<Vec<(String, Tensor)>> {
        let entry = self
            .manifest
            .get("state_blobs")
            .and_then(|a| a.get(name))
            .ok_or_else(|| err!("state blob '{name}' not in manifest"))?;
        let file = entry
            .get("file")
            .and_then(Json::as_str)
            .ok_or_else(|| err!("blob '{name}' missing file"))?;
        let bytes = std::fs::read(self.artifacts_dir.join(file))?;
        let mut out = Vec::new();
        for t in entry.get("tensors").and_then(Json::as_arr).unwrap_or(&[]) {
            let tname = t.get("name").and_then(Json::as_str).unwrap_or("");
            let off = t.get("offset").and_then(Json::as_usize).unwrap_or(0);
            let nbytes = t.get("nbytes").and_then(Json::as_usize).unwrap_or(0);
            let dtype = t.get("dtype").and_then(Json::as_str).unwrap_or("float32");
            let raw = bytes
                .get(off..off + nbytes)
                .ok_or_else(|| err!("blob '{name}' truncated"))?;
            let tensor = match DType::from_str(dtype)? {
                DType::F32 => Tensor::F32(
                    raw.chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                ),
                DType::I32 => Tensor::I32(
                    raw.chunks_exact(4)
                        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                ),
            };
            out.push((tname.to_string(), tensor));
        }
        Ok(out)
    }
}
