//! Structure relaxation — the OC20 workload (find the minimum-energy
//! geometry of an adsorbate-catalyst complex by following forces).
//!
//! FIRE (Fast Inertial Relaxation Engine, Bitzek et al. 2006): MD-like
//! descent with adaptive time step and velocity mixing; the standard
//! relaxer in atomistic pipelines (ASE's default alongside L-BFGS).
//! Force providers are pluggable, so the same driver runs on the
//! classical potential (ground truth), the served GauntNet model, or a
//! periodic system via [`crate::md::potential::PeriodicPotential`]
//! (minimum-image forces through a skin-buffered Verlet list).

/// Force provider abstraction: positions -> (energy, forces).
/// Implementations under periodic boundary conditions carry their own
/// [`crate::md::neighbor::Cell`]; positions may drift outside the box —
/// providers apply minimum image internally and never wrap the caller's
/// coordinates.
pub trait ForceProvider {
    fn energy_forces(&mut self, pos: &[[f64; 3]]) -> (f64, Vec<[f64; 3]>);
}

impl<F> ForceProvider for F
where
    F: FnMut(&[[f64; 3]]) -> (f64, Vec<[f64; 3]>),
{
    fn energy_forces(&mut self, pos: &[[f64; 3]]) -> (f64, Vec<[f64; 3]>) {
        self(pos)
    }
}

/// FIRE hyperparameters (standard values from the paper).
#[derive(Clone, Copy, Debug)]
pub struct FireConfig {
    pub dt_start: f64,
    pub dt_max: f64,
    pub n_min: usize,
    pub f_inc: f64,
    pub f_dec: f64,
    pub alpha_start: f64,
    pub f_alpha: f64,
    /// stop when max |F_i| < fmax
    pub fmax: f64,
    pub max_steps: usize,
}

impl Default for FireConfig {
    fn default() -> Self {
        FireConfig {
            dt_start: 0.02,
            dt_max: 0.2,
            n_min: 5,
            f_inc: 1.1,
            f_dec: 0.5,
            alpha_start: 0.1,
            f_alpha: 0.99,
            fmax: 1e-3,
            max_steps: 2000,
        }
    }
}

/// Relaxation outcome.
#[derive(Clone, Debug)]
pub struct RelaxResult {
    pub pos: Vec<[f64; 3]>,
    pub energy: f64,
    pub max_force: f64,
    pub steps: usize,
    pub converged: bool,
    /// energy at every step (monotone-ish descent diagnostic)
    pub energy_trace: Vec<f64>,
}

fn max_force_norm(f: &[[f64; 3]]) -> f64 {
    f.iter()
        .map(|v| (v[0] * v[0] + v[1] * v[1] + v[2] * v[2]).sqrt())
        .fold(0.0, f64::max)
}

/// Run FIRE relaxation from `pos0`.
pub fn fire_relax<P: ForceProvider>(
    provider: &mut P,
    pos0: &[[f64; 3]],
    cfg: FireConfig,
) -> RelaxResult {
    let n = pos0.len();
    let mut pos = pos0.to_vec();
    let mut vel = vec![[0.0f64; 3]; n];
    let mut dt = cfg.dt_start;
    let mut alpha = cfg.alpha_start;
    let mut n_pos = 0usize;
    let (mut energy, mut forces) = provider.energy_forces(&pos);
    let mut trace = vec![energy];
    let mut steps = 0usize;
    while steps < cfg.max_steps {
        let fmax = max_force_norm(&forces);
        if fmax < cfg.fmax {
            return RelaxResult {
                pos,
                energy,
                max_force: fmax,
                steps,
                converged: true,
                energy_trace: trace,
            };
        }
        // P = F . v
        let p: f64 = forces
            .iter()
            .zip(&vel)
            .map(|(f, v)| f[0] * v[0] + f[1] * v[1] + f[2] * v[2])
            .sum();
        if p > 0.0 {
            n_pos += 1;
            // velocity mixing toward the force direction
            let vnorm: f64 = vel
                .iter()
                .map(|v| v[0] * v[0] + v[1] * v[1] + v[2] * v[2])
                .sum::<f64>()
                .sqrt();
            let fnorm: f64 = forces
                .iter()
                .map(|f| f[0] * f[0] + f[1] * f[1] + f[2] * f[2])
                .sum::<f64>()
                .sqrt()
                .max(1e-30);
            for (v, f) in vel.iter_mut().zip(&forces) {
                for k in 0..3 {
                    v[k] = (1.0 - alpha) * v[k] + alpha * vnorm * f[k] / fnorm;
                }
            }
            if n_pos > cfg.n_min {
                dt = (dt * cfg.f_inc).min(cfg.dt_max);
                alpha *= cfg.f_alpha;
            }
        } else {
            n_pos = 0;
            dt *= cfg.f_dec;
            alpha = cfg.alpha_start;
            for v in vel.iter_mut() {
                *v = [0.0; 3];
            }
        }
        // MD (Euler semi-implicit) step
        for i in 0..n {
            for k in 0..3 {
                vel[i][k] += dt * forces[i][k];
                pos[i][k] += dt * vel[i][k];
            }
        }
        let (e, f) = provider.energy_forces(&pos);
        energy = e;
        forces = f;
        trace.push(e);
        steps += 1;
    }
    let fmax = max_force_norm(&forces);
    RelaxResult {
        pos,
        energy,
        max_force: fmax,
        steps,
        converged: fmax < cfg.fmax,
        energy_trace: trace,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::md::potential::{Potential, PotentialKind};
    use crate::util::rng::Rng;

    #[test]
    fn relaxes_lj_dimer_to_minimum() {
        let pot = Potential::lj(1.0, 1.0, 10.0);
        let species = vec![0, 0];
        let mut provider = |pos: &[[f64; 3]]| pot.energy_forces(pos, &species);
        let pos0 = vec![[0.0, 0.0, 0.0], [1.6, 0.0, 0.0]];
        let res = fire_relax(&mut provider, &pos0, FireConfig::default());
        assert!(res.converged, "did not converge: fmax {}", res.max_force);
        let d = {
            let v = [
                res.pos[1][0] - res.pos[0][0],
                res.pos[1][1] - res.pos[0][1],
                res.pos[1][2] - res.pos[0][2],
            ];
            (v[0] * v[0] + v[1] * v[1] + v[2] * v[2]).sqrt()
        };
        let r_min = 2f64.powf(1.0 / 6.0);
        assert!((d - r_min).abs() < 1e-2, "dimer distance {d} vs {r_min}");
    }

    #[test]
    fn energy_decreases_overall() {
        let pot = Potential::lj(1.0, 1.0, 5.0);
        let mut rng = Rng::new(0);
        let pos0: Vec<[f64; 3]> = (0..6)
            .map(|_| [rng.uniform(0.0, 2.5), rng.uniform(0.0, 2.5),
                      rng.uniform(0.0, 2.5)])
            .collect();
        let species = vec![0; 6];
        let mut provider = |pos: &[[f64; 3]]| pot.energy_forces(pos, &species);
        let res = fire_relax(&mut provider, &pos0,
                             FireConfig { max_steps: 3000, ..Default::default() });
        assert!(res.energy < res.energy_trace[0],
                "E {} -> {}", res.energy_trace[0], res.energy);
    }

    #[test]
    fn harmonic_bond_relaxes_to_rest_length() {
        let mut pot = Potential::lj(0.0, 1.0, 0.1); // effectively no LJ
        pot.bonds.push((0, 1, PotentialKind::Harmonic { k: 5.0, r0: 1.3 }));
        let species = vec![0, 0];
        let mut provider = |pos: &[[f64; 3]]| pot.energy_forces(pos, &species);
        let res = fire_relax(
            &mut provider,
            &[[0.0; 3], [2.0, 0.0, 0.0]],
            FireConfig::default(),
        );
        assert!(res.converged);
        assert!((res.pos[1][0] - res.pos[0][0] - 1.3).abs() < 1e-2);
    }

    #[test]
    fn already_converged_returns_immediately() {
        let pot = Potential::lj(1.0, 1.0, 10.0);
        let species = vec![0, 0];
        let r_min = 2f64.powf(1.0 / 6.0);
        let mut provider = |pos: &[[f64; 3]]| pot.energy_forces(pos, &species);
        let res = fire_relax(
            &mut provider,
            &[[0.0; 3], [r_min, 0.0, 0.0]],
            FireConfig { fmax: 1e-2, ..Default::default() },
        );
        assert!(res.converged);
        assert_eq!(res.steps, 0);
    }

    #[test]
    fn respects_max_steps() {
        let pot = Potential::lj(1.0, 1.0, 5.0);
        let species = vec![0, 0];
        let mut provider = |pos: &[[f64; 3]]| pot.energy_forces(pos, &species);
        let res = fire_relax(
            &mut provider,
            &[[0.0; 3], [3.0, 0.0, 0.0]],
            FireConfig { max_steps: 3, fmax: 1e-12, ..Default::default() },
        );
        assert_eq!(res.steps, 3);
        assert!(!res.converged);
    }
}
