//! Neighbor search: brute force, cell lists, periodic boundary
//! conditions, and skin-buffered Verlet lists.
//!
//! Three layers (DESIGN.md §13):
//!
//! * **Open boundary** — [`neighbors_brute`] / [`neighbors_cell`], the
//!   original bounding-box grid the coordinator uses to build the
//!   (padded) edge lists the compiled model consumes.  Unchanged
//!   behavior, pinned by the golden cross-validation suite.
//! * **Periodic** — a [`Cell`] lattice (orthorhombic or general
//!   triclinic) with the minimum-image convention, and an O(N)
//!   wrapped-cell builder ([`neighbors_periodic_cell`], parallel
//!   variant [`neighbors_periodic_par`]) whose edges carry an integer
//!   image **shift**: the displacement a consumer must use is
//!   `pos[i] - pos[j] + shift · H` (rows of `H` are the lattice
//!   vectors).  Exactness requires `r_cut <= min_width / 2` (asserted),
//!   where a pair has at most one image in range — the contract every
//!   property test checks against [`neighbors_periodic_brute`].
//! * **Verlet** — [`VerletList`] builds at `r_cut + skin` and skips
//!   rebuilds while every atom has moved less than `skin / 2` since the
//!   reference build.  Reuse steps touch no allocator (gated by
//!   `tests/alloc_regression.rs`); rebuilds reuse retained scratch and
//!   edge capacity, so steady-state trajectories stop allocating once
//!   the high-water mark is reached.

use crate::util::pool;

/// All directed pairs (i, j), i != j, with |r_i - r_j| < r_cut.
pub fn neighbors_brute(pos: &[[f64; 3]], r_cut: f64) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let rc2 = r_cut * r_cut;
    for i in 0..pos.len() {
        for j in 0..pos.len() {
            if i == j {
                continue;
            }
            let d2 = dist2(pos[i], pos[j]);
            if d2 < rc2 {
                out.push((i, j));
            }
        }
    }
    out
}

/// Cell-list neighbor search — O(N) for homogeneous densities.
pub fn neighbors_cell(pos: &[[f64; 3]], r_cut: f64) -> Vec<(usize, usize)> {
    if pos.is_empty() {
        return Vec::new();
    }
    let mut lo = [f64::INFINITY; 3];
    let mut hi = [f64::NEG_INFINITY; 3];
    for p in pos {
        for k in 0..3 {
            lo[k] = lo[k].min(p[k]);
            hi[k] = hi[k].max(p[k]);
        }
    }
    // The grid is sized from bounding-box extent / cell width.  For a
    // SPARSE system (two atoms 1e5 apart, r_cut = 0.5) that naive sizing
    // asks for ~10^15 buckets — an OOM, not a slowdown.  Cap the total
    // bucket count at a budget proportional to the atom count and grow
    // the cell width until the grid fits.  A cell width >= r_cut keeps
    // the 3x3x3 neighborhood walk correct (every pair within r_cut still
    // lands in adjacent cells); bigger cells only cost extra distance
    // checks, degrading smoothly toward brute force instead of crashing.
    let budget = (4 * pos.len()).max(64) as f64;
    let mut cell = r_cut.max(1e-9);
    loop {
        let est: f64 = (0..3)
            .map(|k| ((hi[k] - lo[k]) / cell).floor() + 1.0)
            .product();
        if est <= budget || !est.is_finite() {
            break;
        }
        cell *= 2.0;
    }
    let dims: [usize; 3] = std::array::from_fn(|k| {
        (((hi[k] - lo[k]) / cell).floor() as usize + 1).max(1)
    });
    let cell_of = |p: &[f64; 3]| -> [usize; 3] {
        std::array::from_fn(|k| {
            (((p[k] - lo[k]) / cell).floor() as usize).min(dims[k] - 1)
        })
    };
    let idx = |c: [usize; 3]| (c[0] * dims[1] + c[1]) * dims[2] + c[2];
    let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); dims[0] * dims[1] * dims[2]];
    for (i, p) in pos.iter().enumerate() {
        buckets[idx(cell_of(p))].push(i);
    }
    let rc2 = r_cut * r_cut;
    let mut out = Vec::new();
    for (i, p) in pos.iter().enumerate() {
        let c = cell_of(p);
        for dx in -1i64..=1 {
            for dy in -1i64..=1 {
                for dz in -1i64..=1 {
                    let nc = [
                        c[0] as i64 + dx,
                        c[1] as i64 + dy,
                        c[2] as i64 + dz,
                    ];
                    if nc.iter().zip(&dims).any(|(v, d)| *v < 0 || *v >= *d as i64)
                    {
                        continue;
                    }
                    let b = idx([nc[0] as usize, nc[1] as usize, nc[2] as usize]);
                    for &j in &buckets[b] {
                        if j != i && dist2(*p, pos[j]) < rc2 {
                            out.push((i, j));
                        }
                    }
                }
            }
        }
    }
    out
}

#[inline]
fn dist2(a: [f64; 3], b: [f64; 3]) -> f64 {
    let d = [a[0] - b[0], a[1] - b[1], a[2] - b[2]];
    d[0] * d[0] + d[1] * d[1] + d[2] * d[2]
}

#[inline]
fn norm2(d: [f64; 3]) -> f64 {
    d[0] * d[0] + d[1] * d[1] + d[2] * d[2]
}

// ---------------------------------------------------------------------
// Periodic cells
// ---------------------------------------------------------------------

/// A periodic simulation cell: three lattice vectors (rows of `h`),
/// orthorhombic or general triclinic.
///
/// Conventions (DESIGN.md §13):
/// * Cartesian from fractional: `r = f · H` (i.e. `r_k = Σ_a f_a
///   h[a][k]`); fractional from Cartesian via the cached inverse.
/// * [`Cell::min_image`] maps a raw displacement `d_raw = r_i - r_j` to
///   the minimum-image displacement `d = d_raw + shift · H` by rounding
///   the fractional components — exact whenever the relevant cutoff is
///   at most [`Cell::max_cutoff`] = half the minimum perpendicular
///   width, the precondition asserted by every periodic builder.
#[derive(Clone, Debug)]
pub struct Cell {
    /// Rows are the lattice vectors a, b, c.
    h: [[f64; 3]; 3],
    /// Inverse of H^T: maps Cartesian to fractional coordinates.
    hinv_t: [[f64; 3]; 3],
    /// Perpendicular width of the cell along each lattice direction.
    widths: [f64; 3],
}

impl Cell {
    /// Orthorhombic cell with edge lengths `(lx, ly, lz)`.
    pub fn orthorhombic(lx: f64, ly: f64, lz: f64) -> Cell {
        Cell::triclinic([
            [lx, 0.0, 0.0],
            [0.0, ly, 0.0],
            [0.0, 0.0, lz],
        ])
    }

    /// Cubic cell with edge length `l`.
    pub fn cubic(l: f64) -> Cell {
        Cell::orthorhombic(l, l, l)
    }

    /// General triclinic cell; `h` rows are the lattice vectors.
    /// Panics on a (near-)singular lattice.
    pub fn triclinic(h: [[f64; 3]; 3]) -> Cell {
        let cross = |a: [f64; 3], b: [f64; 3]| -> [f64; 3] {
            [
                a[1] * b[2] - a[2] * b[1],
                a[2] * b[0] - a[0] * b[2],
                a[0] * b[1] - a[1] * b[0],
            ]
        };
        let dot = |a: [f64; 3], b: [f64; 3]| -> f64 {
            a[0] * b[0] + a[1] * b[1] + a[2] * b[2]
        };
        let bxc = cross(h[1], h[2]);
        let cxa = cross(h[2], h[0]);
        let axb = cross(h[0], h[1]);
        let vol = dot(h[0], bxc);
        assert!(
            vol.abs() > 1e-12,
            "Cell::triclinic: singular lattice (volume {vol:.3e})"
        );
        // frac = (H^T)^{-1} r.  Columns of H^T are the lattice vectors,
        // so rows of the inverse are the reciprocal vectors / volume.
        let hinv_t = [
            [bxc[0] / vol, bxc[1] / vol, bxc[2] / vol],
            [cxa[0] / vol, cxa[1] / vol, cxa[2] / vol],
            [axb[0] / vol, axb[1] / vol, axb[2] / vol],
        ];
        let widths = [
            vol.abs() / norm2(bxc).sqrt(),
            vol.abs() / norm2(cxa).sqrt(),
            vol.abs() / norm2(axb).sqrt(),
        ];
        Cell { h, hinv_t, widths }
    }

    /// The lattice vectors (rows).
    pub fn lattice(&self) -> &[[f64; 3]; 3] {
        &self.h
    }

    pub fn volume(&self) -> f64 {
        (self.widths[0] * norm2(crossn(self.h[1], self.h[2])).sqrt()).abs()
    }

    /// Minimum perpendicular width across the three lattice directions.
    pub fn min_width(&self) -> f64 {
        self.widths[0].min(self.widths[1]).min(self.widths[2])
    }

    /// Largest cutoff for which the minimum-image convention is exact
    /// (a pair then has at most one periodic image in range).
    pub fn max_cutoff(&self) -> f64 {
        0.5 * self.min_width()
    }

    /// Fractional coordinates of a Cartesian point.
    #[inline]
    pub fn frac(&self, r: [f64; 3]) -> [f64; 3] {
        std::array::from_fn(|a| {
            self.hinv_t[a][0] * r[0]
                + self.hinv_t[a][1] * r[1]
                + self.hinv_t[a][2] * r[2]
        })
    }

    /// Cartesian point from fractional coordinates.
    #[inline]
    pub fn cart(&self, f: [f64; 3]) -> [f64; 3] {
        std::array::from_fn(|k| {
            f[0] * self.h[0][k] + f[1] * self.h[1][k] + f[2] * self.h[2][k]
        })
    }

    /// The Cartesian lattice translation `shift · H`.
    #[inline]
    pub fn shift_vector(&self, shift: [i32; 3]) -> [f64; 3] {
        self.cart([shift[0] as f64, shift[1] as f64, shift[2] as f64])
    }

    /// Wrap a Cartesian point into the home cell (fractional [0, 1)).
    pub fn wrap(&self, r: [f64; 3]) -> [f64; 3] {
        let f = self.frac(r);
        self.cart(std::array::from_fn(|a| wrap01(f[a])))
    }

    /// Minimum-image displacement: returns `(d, shift)` with
    /// `d = d_raw + shift · H` the nearest-image displacement.  Exact
    /// for distances below [`Cell::max_cutoff`].
    #[inline]
    pub fn min_image(&self, d_raw: [f64; 3]) -> ([f64; 3], [i32; 3]) {
        let f = self.frac(d_raw);
        let shift: [i32; 3] = std::array::from_fn(|a| -f[a].round() as i32);
        let sv = self.shift_vector(shift);
        (
            [d_raw[0] + sv[0], d_raw[1] + sv[1], d_raw[2] + sv[2]],
            shift,
        )
    }
}

#[inline]
fn crossn(a: [f64; 3], b: [f64; 3]) -> [f64; 3] {
    [
        a[1] * b[2] - a[2] * b[1],
        a[2] * b[0] - a[0] * b[2],
        a[0] * b[1] - a[1] * b[0],
    ]
}

/// Map a fractional coordinate into [0, 1), robust to the `x - floor(x)
/// == 1.0` rounding corner for tiny negative inputs.
#[inline]
fn wrap01(x: f64) -> f64 {
    let w = x - x.floor();
    if w >= 1.0 { 0.0 } else { w }
}

/// One directed periodic edge: the consumer-side displacement is
/// `pos[i] - pos[j] + shift · H` ([`Cell::shift_vector`]).  The reverse
/// edge `(j, i, -shift)` is always present.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Edge {
    pub i: usize,
    pub j: usize,
    pub shift: [i32; 3],
}

fn assert_mic_cutoff(cell: &Cell, r_cut: f64) {
    assert!(
        r_cut <= cell.max_cutoff() + 1e-9,
        "periodic cutoff {r_cut} exceeds half the minimum cell width \
         ({}): the minimum-image convention would miss images",
        cell.max_cutoff()
    );
}

/// Brute-force minimum-image oracle: all directed pairs (i, j), i != j,
/// whose nearest-image distance is below `r_cut`.  O(N^2); the property
/// suite's ground truth for the cell-list builders.
pub fn neighbors_periodic_brute(
    pos: &[[f64; 3]], cell: &Cell, r_cut: f64,
) -> Vec<Edge> {
    assert_mic_cutoff(cell, r_cut);
    let rc2 = r_cut * r_cut;
    let mut out = Vec::new();
    for i in 0..pos.len() {
        for j in 0..pos.len() {
            if i == j {
                continue;
            }
            let d_raw = [
                pos[i][0] - pos[j][0],
                pos[i][1] - pos[j][1],
                pos[i][2] - pos[j][2],
            ];
            let (d, shift) = cell.min_image(d_raw);
            if norm2(d) < rc2 {
                out.push(Edge { i, j, shift });
            }
        }
    }
    out
}

/// Retained workspace of the periodic (and Verlet) cell builders:
/// linked-cell `head`/`next` arrays plus the wrapped fractional
/// coordinates, reused across rebuilds so steady-state trajectories do
/// not allocate.
#[derive(Clone, Debug, Default)]
pub struct CellListScratch {
    head: Vec<i32>,
    next: Vec<i32>,
    fw: Vec<[f64; 3]>,
}

/// Grid dimensions for a periodic cell list: as many bins per axis as
/// fit a perpendicular width of `r_cut` (so the wrapped 3x3x3 walk is
/// exact), capped at a total-bucket budget proportional to the atom
/// count (a near-empty giant box must not allocate a giant grid —
/// coarser bins only add distance checks, never miss pairs).
fn periodic_grid_dims(cell: &Cell, r_cut: f64, n_atoms: usize) -> [usize; 3] {
    let budget = (4 * n_atoms).max(64);
    let mut dims: [usize; 3] = std::array::from_fn(|k| {
        ((cell.widths[k] / r_cut).floor() as usize).max(1)
    });
    while dims[0] * dims[1] * dims[2] > budget {
        let k = (0..3).max_by_key(|&k| dims[k]).unwrap();
        if dims[k] == 1 {
            break;
        }
        dims[k] = dims[k].div_ceil(2);
    }
    dims
}

/// Bin the wrapped fractional coordinates of `pos` into the linked-cell
/// arrays of `scratch`; returns the grid dimensions.
fn bin_atoms(
    pos: &[[f64; 3]], cell: &Cell, r_cut: f64,
    scratch: &mut CellListScratch,
) -> [usize; 3] {
    let dims = periodic_grid_dims(cell, r_cut, pos.len());
    let n_buckets = dims[0] * dims[1] * dims[2];
    scratch.head.clear();
    scratch.head.resize(n_buckets, -1);
    scratch.next.clear();
    scratch.next.resize(pos.len(), -1);
    scratch.fw.clear();
    for (i, p) in pos.iter().enumerate() {
        let f = cell.frac(*p);
        let fw: [f64; 3] = std::array::from_fn(|a| wrap01(f[a]));
        scratch.fw.push(fw);
        let b = bucket_of(fw, dims);
        scratch.next[i] = scratch.head[b];
        scratch.head[b] = i as i32;
    }
    dims
}

#[inline]
fn bucket_of(fw: [f64; 3], dims: [usize; 3]) -> usize {
    let c: [usize; 3] = std::array::from_fn(|k| {
        ((fw[k] * dims[k] as f64) as usize).min(dims[k] - 1)
    });
    (c[0] * dims[1] + c[1]) * dims[2] + c[2]
}

/// Walk the wrapped 3x3x3 neighborhood of atom `i` and append every
/// in-range directed edge.  `cand`/`n_cand` deduplicate bucket indices:
/// along an axis with fewer than three bins the wrapped offsets
/// collide, and a duplicate bucket would emit duplicate edges.
#[inline]
fn walk_atom(
    i: usize, pos: &[[f64; 3]], cell: &Cell, rc2: f64, dims: [usize; 3],
    scratch: &CellListScratch, out: &mut Vec<Edge>,
) {
    let fw = scratch.fw[i];
    let c: [i64; 3] = std::array::from_fn(|k| {
        ((fw[k] * dims[k] as f64) as usize).min(dims[k] - 1) as i64
    });
    let mut cand = [0usize; 27];
    let mut n_cand = 0usize;
    for dx in -1i64..=1 {
        for dy in -1i64..=1 {
            for dz in -1i64..=1 {
                let b = (
                    (c[0] + dx).rem_euclid(dims[0] as i64) as usize
                        * dims[1]
                        + (c[1] + dy).rem_euclid(dims[1] as i64) as usize
                ) * dims[2]
                    + (c[2] + dz).rem_euclid(dims[2] as i64) as usize;
                if !cand[..n_cand].contains(&b) {
                    cand[n_cand] = b;
                    n_cand += 1;
                }
            }
        }
    }
    for &b in &cand[..n_cand] {
        let mut jj = scratch.head[b];
        while jj >= 0 {
            let j = jj as usize;
            if j != i {
                let d_raw = [
                    pos[i][0] - pos[j][0],
                    pos[i][1] - pos[j][1],
                    pos[i][2] - pos[j][2],
                ];
                let (d, shift) = cell.min_image(d_raw);
                if norm2(d) < rc2 {
                    out.push(Edge { i, j, shift });
                }
            }
            jj = scratch.next[j];
        }
    }
}

/// Periodic cell-list build into caller-retained buffers: `out` is
/// cleared and filled with every directed minimum-image edge below
/// `r_cut`.  Allocation-free once `scratch` and `out` have reached
/// their high-water capacity.
pub fn neighbors_periodic_into(
    pos: &[[f64; 3]], cell: &Cell, r_cut: f64,
    scratch: &mut CellListScratch, out: &mut Vec<Edge>,
) {
    assert_mic_cutoff(cell, r_cut);
    out.clear();
    if pos.is_empty() {
        return;
    }
    let dims = bin_atoms(pos, cell, r_cut, scratch);
    let rc2 = r_cut * r_cut;
    for i in 0..pos.len() {
        walk_atom(i, pos, cell, rc2, dims, scratch, out);
    }
}

/// Periodic O(N) cell-list neighbor search (serial convenience).
pub fn neighbors_periodic_cell(
    pos: &[[f64; 3]], cell: &Cell, r_cut: f64,
) -> Vec<Edge> {
    let mut scratch = CellListScratch::default();
    let mut out = Vec::new();
    neighbors_periodic_into(pos, cell, r_cut, &mut scratch, &mut out);
    out
}

/// Parallel periodic build: the atom binning is shared, then the bucket
/// range — the cell blocks — is sharded contiguously across `threads`
/// workers ([`pool::shard_range`]); each worker walks the atoms of its
/// block against the read-only grid into a private edge vector.  The
/// concatenation order follows the block order, so the result is
/// deterministic for a fixed thread count and equal as a SET to the
/// serial build for any.
pub fn neighbors_periodic_par(
    pos: &[[f64; 3]], cell: &Cell, r_cut: f64, threads: usize,
) -> Vec<Edge> {
    assert_mic_cutoff(cell, r_cut);
    if pos.is_empty() {
        return Vec::new();
    }
    let mut scratch = CellListScratch::default();
    let dims = bin_atoms(pos, cell, r_cut, &mut scratch);
    let n_buckets = dims[0] * dims[1] * dims[2];
    let rc2 = r_cut * r_cut;
    let threads = pool::resolve_threads(threads);
    let scratch_ref = &scratch;
    let blocks = pool::shard_range(n_buckets, threads, Vec::new, |b, acc: &mut Vec<Edge>| {
        let mut jj = scratch_ref.head[b];
        while jj >= 0 {
            let i = jj as usize;
            walk_atom(i, pos, cell, rc2, dims, scratch_ref, acc);
            jj = scratch_ref.next[i];
        }
    });
    let mut out = Vec::with_capacity(blocks.iter().map(Vec::len).sum());
    for b in blocks {
        out.extend_from_slice(&b);
    }
    out
}

// ---------------------------------------------------------------------
// Verlet (skin) lists
// ---------------------------------------------------------------------

/// A skin-buffered neighbor list: built once at `r_cut + skin`, then
/// reused while no atom has moved more than `skin / 2` from its
/// position at build time (any pair can then have approached by at most
/// `skin`, so every pair currently inside `r_cut` is still listed, with
/// its image shift still the nearest image).  Consumers re-check the
/// true distance per edge; [`VerletList::for_each_pair`] does exactly
/// that over undirected pairs.
///
/// Reuse steps ([`VerletList::update`] returning `false`) never touch
/// the allocator; rebuilds reuse the retained scratch and edge/ref
/// capacity (asserted by `tests/alloc_regression.rs`).
pub struct VerletList {
    pub r_cut: f64,
    pub skin: f64,
    cell: Option<Cell>,
    edges: Vec<Edge>,
    ref_pos: Vec<[f64; 3]>,
    scratch: CellListScratch,
    built: bool,
    /// Rebuild / reuse counters (rebuild-rate observability for the
    /// `md_neighbor` bench).
    pub rebuilds: usize,
    pub reuses: usize,
}

impl VerletList {
    /// Open-boundary list (all image shifts zero).
    pub fn open(r_cut: f64, skin: f64) -> VerletList {
        assert!(r_cut > 0.0 && skin >= 0.0);
        VerletList {
            r_cut,
            skin,
            cell: None,
            edges: Vec::new(),
            ref_pos: Vec::new(),
            scratch: CellListScratch::default(),
            built: false,
            rebuilds: 0,
            reuses: 0,
        }
    }

    /// Periodic list.  Requires `r_cut + skin <= cell.max_cutoff()`:
    /// the build radius itself must satisfy the minimum-image bound so
    /// a stored image stays the nearest one across the skin lifetime.
    pub fn periodic(cell: Cell, r_cut: f64, skin: f64) -> VerletList {
        assert!(r_cut > 0.0 && skin >= 0.0);
        assert_mic_cutoff(&cell, r_cut + skin);
        VerletList { cell: Some(cell), ..VerletList::open(r_cut, skin) }
    }

    pub fn cell(&self) -> Option<&Cell> {
        self.cell.as_ref()
    }

    /// The current candidate edges (directed, within `r_cut + skin` at
    /// the last rebuild).  Consumers must re-check distances.
    pub fn edges(&self) -> &[Edge] {
        &self.edges
    }

    /// Ensure the list is valid for `pos`; returns `true` if it was
    /// rebuilt, `false` on a (allocation-free) reuse step.
    pub fn update(&mut self, pos: &[[f64; 3]]) -> bool {
        if self.needs_rebuild(pos) {
            self.rebuild(pos);
            self.rebuilds += 1;
            true
        } else {
            self.reuses += 1;
            false
        }
    }

    fn needs_rebuild(&self, pos: &[[f64; 3]]) -> bool {
        if !self.built || pos.len() != self.ref_pos.len() {
            return true;
        }
        if self.skin == 0.0 {
            return true;
        }
        let limit2 = 0.25 * self.skin * self.skin;
        pos.iter()
            .zip(&self.ref_pos)
            .any(|(p, q)| dist2(*p, *q) >= limit2)
    }

    fn rebuild(&mut self, pos: &[[f64; 3]]) {
        let r_build = self.r_cut + self.skin;
        match &self.cell {
            Some(cell) => {
                neighbors_periodic_into(
                    pos, cell, r_build, &mut self.scratch, &mut self.edges,
                );
            }
            None => {
                open_build_into(
                    pos, r_build, &mut self.scratch, &mut self.edges,
                );
            }
        }
        self.ref_pos.clear();
        self.ref_pos.extend_from_slice(pos);
        self.built = true;
    }

    /// Visit every undirected pair currently within `r_cut`:
    /// `f(i, j, d, r2)` with `d = pos[i] - pos[j] + shift · H` the
    /// minimum-image displacement and `r2 = |d|^2 < r_cut^2`; each pair
    /// is visited once, with `i < j`.  Allocation-free.
    pub fn for_each_pair<F: FnMut(usize, usize, [f64; 3], f64)>(
        &self, pos: &[[f64; 3]], mut f: F,
    ) {
        let rc2 = self.r_cut * self.r_cut;
        for e in &self.edges {
            if e.i >= e.j {
                continue;
            }
            let mut d = [
                pos[e.i][0] - pos[e.j][0],
                pos[e.i][1] - pos[e.j][1],
                pos[e.i][2] - pos[e.j][2],
            ];
            if let Some(cell) = &self.cell {
                let sv = cell.shift_vector(e.shift);
                d = [d[0] + sv[0], d[1] + sv[1], d[2] + sv[2]];
            }
            let r2 = norm2(d);
            if r2 < rc2 {
                f(e.i, e.j, d, r2);
            }
        }
    }
}

/// Open-boundary analog of [`neighbors_periodic_into`]: the bounding-box
/// grid of [`neighbors_cell`], rebuilt over retained linked-cell
/// scratch; every edge carries a zero shift.
fn open_build_into(
    pos: &[[f64; 3]], r_cut: f64,
    scratch: &mut CellListScratch, out: &mut Vec<Edge>,
) {
    out.clear();
    if pos.is_empty() {
        return;
    }
    let mut lo = [f64::INFINITY; 3];
    let mut hi = [f64::NEG_INFINITY; 3];
    for p in pos {
        for k in 0..3 {
            lo[k] = lo[k].min(p[k]);
            hi[k] = hi[k].max(p[k]);
        }
    }
    let budget = (4 * pos.len()).max(64) as f64;
    let mut w = r_cut.max(1e-9);
    loop {
        let est: f64 = (0..3)
            .map(|k| ((hi[k] - lo[k]) / w).floor() + 1.0)
            .product();
        if est <= budget || !est.is_finite() {
            break;
        }
        w *= 2.0;
    }
    let dims: [usize; 3] = std::array::from_fn(|k| {
        (((hi[k] - lo[k]) / w).floor() as usize + 1).max(1)
    });
    let cell_of = |p: &[f64; 3]| -> [i64; 3] {
        std::array::from_fn(|k| {
            ((((p[k] - lo[k]) / w).floor() as usize).min(dims[k] - 1)) as i64
        })
    };
    let idx = |c: [usize; 3]| (c[0] * dims[1] + c[1]) * dims[2] + c[2];
    let n_buckets = dims[0] * dims[1] * dims[2];
    scratch.head.clear();
    scratch.head.resize(n_buckets, -1);
    scratch.next.clear();
    scratch.next.resize(pos.len(), -1);
    for (i, p) in pos.iter().enumerate() {
        let c = cell_of(p);
        let b = idx([c[0] as usize, c[1] as usize, c[2] as usize]);
        scratch.next[i] = scratch.head[b];
        scratch.head[b] = i as i32;
    }
    let rc2 = r_cut * r_cut;
    for (i, p) in pos.iter().enumerate() {
        let c = cell_of(p);
        for dx in -1i64..=1 {
            for dy in -1i64..=1 {
                for dz in -1i64..=1 {
                    let nc = [c[0] + dx, c[1] + dy, c[2] + dz];
                    if nc.iter().zip(&dims).any(|(v, d)| *v < 0 || *v >= *d as i64)
                    {
                        continue;
                    }
                    let b = idx([
                        nc[0] as usize, nc[1] as usize, nc[2] as usize,
                    ]);
                    let mut jj = scratch.head[b];
                    while jj >= 0 {
                        let j = jj as usize;
                        if j != i && dist2(*p, pos[j]) < rc2 {
                            out.push(Edge { i, j, shift: [0; 3] });
                        }
                        jj = scratch.next[j];
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, PropConfig};

    #[test]
    fn brute_simple() {
        let pos = vec![[0.0, 0.0, 0.0], [1.0, 0.0, 0.0], [5.0, 0.0, 0.0]];
        let n = neighbors_brute(&pos, 2.0);
        assert_eq!(n, vec![(0, 1), (1, 0)]);
    }

    #[test]
    fn cell_matches_brute_property() {
        check("cell-list == brute-force", PropConfig { cases: 24, seed: 5 },
              |rng, case| {
            let n = 4 + case % 40;
            let pos: Vec<[f64; 3]> = (0..n)
                .map(|_| [rng.uniform(-3.0, 3.0), rng.uniform(-3.0, 3.0),
                          rng.uniform(-3.0, 3.0)])
                .collect();
            let rc = rng.uniform(0.5, 2.5);
            let mut a = neighbors_brute(&pos, rc);
            let mut b = neighbors_cell(&pos, rc);
            a.sort_unstable();
            b.sort_unstable();
            if a == b {
                Ok(())
            } else {
                Err(format!("mismatch: brute {} vs cell {}", a.len(), b.len()))
            }
        });
    }

    #[test]
    fn directed_symmetry() {
        let pos = vec![[0.0; 3], [0.5, 0.5, 0.5], [0.9, 0.0, 0.1]];
        let n = neighbors_cell(&pos, 1.5);
        for (i, j) in &n {
            assert!(n.contains(&(*j, *i)));
        }
    }

    #[test]
    fn empty_input() {
        assert!(neighbors_cell(&[], 1.0).is_empty());
        assert!(
            neighbors_periodic_cell(&[], &Cell::cubic(5.0), 1.0).is_empty()
        );
    }

    #[test]
    fn sparse_extreme_extent_does_not_allocate_the_world() {
        // Pre-fix this asked for ((1e5/0.5)+1)^2 * 1 ≈ 4e10 buckets from
        // two atoms alone (and ~10^15 with a z extent too); now the cell
        // width grows until the grid fits the 4*n_atoms budget.
        let pos = vec![[0.0, 0.0, 0.0], [1.0e5, 1.0e5, 1.0e5]];
        assert!(neighbors_cell(&pos, 0.5).is_empty());

        // Same geometry, but with a close pair at each end: adjacency
        // must survive the cell-width growth.
        let pos = vec![
            [0.0, 0.0, 0.0],
            [0.3, 0.0, 0.0],
            [1.0e5, 1.0e5, 1.0e5],
            [1.0e5 + 0.3, 1.0e5, 1.0e5],
        ];
        let mut got = neighbors_cell(&pos, 0.5);
        got.sort_unstable();
        assert_eq!(got, vec![(0, 1), (1, 0), (2, 3), (3, 2)]);

        // periodic analog: a huge near-empty box must cap its grid too
        let cell = Cell::cubic(1.0e5);
        let pos = vec![[0.0; 3], [0.3, 0.0, 0.0]];
        let got = neighbors_periodic_cell(&pos, &cell, 0.5);
        assert_eq!(got.len(), 2);
    }

    #[test]
    fn sparse_clusters_match_brute_property() {
        // Widely separated dense clusters: the capped grid must agree
        // with brute force exactly.
        check(
            "sparse cell-list == brute-force",
            PropConfig { cases: 12, seed: 11 },
            |rng, case| {
                let clusters = 2 + case % 3;
                let mut pos = Vec::new();
                for c in 0..clusters {
                    let center = [
                        1.0e4 * c as f64,
                        rng.uniform(-1.0e3, 1.0e3),
                        rng.uniform(-1.0e3, 1.0e3),
                    ];
                    for _ in 0..(3 + case % 6) {
                        pos.push([
                            center[0] + rng.uniform(-1.0, 1.0),
                            center[1] + rng.uniform(-1.0, 1.0),
                            center[2] + rng.uniform(-1.0, 1.0),
                        ]);
                    }
                }
                let rc = rng.uniform(0.5, 2.0);
                let mut a = neighbors_brute(&pos, rc);
                let mut b = neighbors_cell(&pos, rc);
                a.sort_unstable();
                b.sort_unstable();
                if a == b {
                    Ok(())
                } else {
                    Err(format!(
                        "mismatch: brute {} vs cell {}",
                        a.len(),
                        b.len()
                    ))
                }
            },
        );
    }

    // --- periodic unit tests (the full property suite lives in
    // tests/periodic_property.rs) ---

    #[test]
    fn cell_round_trips_and_widths() {
        let cell = Cell::orthorhombic(4.0, 6.0, 10.0);
        assert!((cell.min_width() - 4.0).abs() < 1e-12);
        assert!((cell.max_cutoff() - 2.0).abs() < 1e-12);
        let r = [1.3, -2.1, 17.9];
        let back = cell.cart(cell.frac(r));
        for k in 0..3 {
            assert!((back[k] - r[k]).abs() < 1e-12);
        }
        let w = cell.wrap([5.0, -1.0, 21.0]);
        assert!((w[0] - 1.0).abs() < 1e-12);
        assert!((w[1] - 5.0).abs() < 1e-12);
        assert!((w[2] - 1.0).abs() < 1e-12);

        // triclinic: a sheared cube keeps volume, loses width
        let tri = Cell::triclinic([
            [4.0, 0.0, 0.0],
            [2.0, 4.0, 0.0],
            [0.0, 0.0, 4.0],
        ]);
        assert!(tri.min_width() < 4.0 - 1e-9);
        let f = tri.frac([6.0, 4.0, 0.0]); // = a + b
        assert!((f[0] - 1.0).abs() < 1e-12 && (f[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn min_image_picks_nearest() {
        let cell = Cell::cubic(10.0);
        let (d, s) = cell.min_image([9.0, 0.0, 0.0]);
        assert!((d[0] + 1.0).abs() < 1e-12);
        assert_eq!(s, [-1, 0, 0]);
        let (d, s) = cell.min_image([-12.0, 4.0, 26.0]);
        assert!((d[0] + 2.0).abs() < 1e-12);
        assert!((d[1] - 4.0).abs() < 1e-12);
        assert!((d[2] + 4.0).abs() < 1e-12);
        assert_eq!(s, [1, 0, -3]);
    }

    #[test]
    fn periodic_wraparound_pair_found() {
        let cell = Cell::cubic(10.0);
        // neighbors only through the boundary
        let pos = vec![[0.2, 5.0, 5.0], [9.9, 5.0, 5.0]];
        let mut got = neighbors_periodic_cell(&pos, &cell, 1.0);
        got.sort_unstable();
        assert_eq!(
            got,
            vec![
                Edge { i: 0, j: 1, shift: [1, 0, 0] },
                Edge { i: 1, j: 0, shift: [-1, 0, 0] },
            ]
        );
        // consumer-side displacement reconstructs the true distance
        let e = got[0];
        let sv = cell.shift_vector(e.shift);
        let d = [
            pos[e.i][0] - pos[e.j][0] + sv[0],
            pos[e.i][1] - pos[e.j][1] + sv[1],
            pos[e.i][2] - pos[e.j][2] + sv[2],
        ];
        assert!((norm2(d).sqrt() - 0.3).abs() < 1e-12);
    }

    #[test]
    fn periodic_matches_brute_oracle_property() {
        check(
            "periodic cell-list == minimum-image brute force",
            PropConfig { cases: 20, seed: 17 },
            |rng, case| {
                let l = rng.uniform(4.0, 8.0);
                let cell = if case % 3 == 0 {
                    Cell::triclinic([
                        [l, 0.0, 0.0],
                        [0.3 * l, 1.1 * l, 0.0],
                        [0.1 * l, 0.2 * l, 0.9 * l],
                    ])
                } else {
                    Cell::orthorhombic(l, 1.2 * l, 0.8 * l)
                };
                let n = 6 + case % 30;
                // positions deliberately NOT pre-wrapped
                let pos: Vec<[f64; 3]> = (0..n)
                    .map(|_| {
                        [
                            rng.uniform(-2.0 * l, 2.0 * l),
                            rng.uniform(-2.0 * l, 2.0 * l),
                            rng.uniform(-2.0 * l, 2.0 * l),
                        ]
                    })
                    .collect();
                // cutoffs all the way up to the MIC bound
                let rc = rng.uniform(0.3, 1.0) * cell.max_cutoff();
                let mut a = neighbors_periodic_brute(&pos, &cell, rc);
                let mut b = neighbors_periodic_cell(&pos, &cell, rc);
                let mut c = neighbors_periodic_par(&pos, &cell, rc, 3);
                a.sort_unstable();
                b.sort_unstable();
                c.sort_unstable();
                if a != b {
                    return Err(format!(
                        "cell-list mismatch: brute {} vs cell {}",
                        a.len(), b.len()
                    ));
                }
                if a != c {
                    return Err(format!(
                        "parallel mismatch: brute {} vs par {}",
                        a.len(), c.len()
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn verlet_reuses_until_skin_and_stays_exact() {
        let cell = Cell::cubic(8.0);
        let mut pos: Vec<[f64; 3]> = Vec::new();
        for i in 0..4 {
            for j in 0..4 {
                for k in 0..4 {
                    pos.push([2.0 * i as f64, 2.0 * j as f64,
                              2.0 * k as f64]);
                }
            }
        }
        let mut vl = VerletList::periodic(cell.clone(), 2.2, 0.8);
        assert!(vl.update(&pos), "first update must build");
        assert!(!vl.update(&pos), "unmoved positions reuse the list");
        // nudge every atom by less than skin/2: still a reuse
        for p in pos.iter_mut() {
            p[0] += 0.3;
        }
        assert!(!vl.update(&pos));
        // the reused list is still exact at r_cut
        let mut got = Vec::new();
        vl.for_each_pair(&pos, |i, j, _, _| got.push((i, j)));
        let mut want: Vec<(usize, usize)> =
            neighbors_periodic_brute(&pos, &cell, 2.2)
                .into_iter()
                .filter(|e| e.i < e.j)
                .map(|e| (e.i, e.j))
                .collect();
        got.sort_unstable();
        want.sort_unstable();
        assert_eq!(got, want);
        // a move past skin/2 triggers a rebuild
        pos[0][1] += 0.5;
        assert!(vl.update(&pos));
        assert_eq!(vl.rebuilds, 2);
        assert_eq!(vl.reuses, 2);
    }

    #[test]
    fn verlet_open_matches_cell_list() {
        let pos: Vec<[f64; 3]> = (0..20)
            .map(|i| {
                let x = i as f64;
                [x * 0.7, (x * 1.3) % 5.0, (x * 2.1) % 4.0]
            })
            .collect();
        let mut vl = VerletList::open(1.5, 0.4);
        vl.update(&pos);
        let mut got = Vec::new();
        vl.for_each_pair(&pos, |i, j, _, _| got.push((i, j)));
        let mut want: Vec<(usize, usize)> = neighbors_cell(&pos, 1.5)
            .into_iter()
            .filter(|(i, j)| i < j)
            .collect();
        got.sort_unstable();
        want.sort_unstable();
        assert_eq!(got, want);
    }

    #[test]
    #[should_panic(expected = "minimum-image")]
    fn cutoff_beyond_mic_bound_panics() {
        let cell = Cell::cubic(4.0);
        let pos = vec![[0.0; 3], [1.0, 0.0, 0.0]];
        let _ = neighbors_periodic_cell(&pos, &cell, 3.0);
    }
}
