//! Neighbor search: brute force and cell lists.  The coordinator uses
//! this to build the (padded) edge lists the compiled model consumes.

/// All directed pairs (i, j), i != j, with |r_i - r_j| < r_cut.
pub fn neighbors_brute(pos: &[[f64; 3]], r_cut: f64) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let rc2 = r_cut * r_cut;
    for i in 0..pos.len() {
        for j in 0..pos.len() {
            if i == j {
                continue;
            }
            let d2 = dist2(pos[i], pos[j]);
            if d2 < rc2 {
                out.push((i, j));
            }
        }
    }
    out
}

/// Cell-list neighbor search — O(N) for homogeneous densities.
pub fn neighbors_cell(pos: &[[f64; 3]], r_cut: f64) -> Vec<(usize, usize)> {
    if pos.is_empty() {
        return Vec::new();
    }
    let mut lo = [f64::INFINITY; 3];
    let mut hi = [f64::NEG_INFINITY; 3];
    for p in pos {
        for k in 0..3 {
            lo[k] = lo[k].min(p[k]);
            hi[k] = hi[k].max(p[k]);
        }
    }
    // The grid is sized from bounding-box extent / cell width.  For a
    // SPARSE system (two atoms 1e5 apart, r_cut = 0.5) that naive sizing
    // asks for ~10^15 buckets — an OOM, not a slowdown.  Cap the total
    // bucket count at a budget proportional to the atom count and grow
    // the cell width until the grid fits.  A cell width >= r_cut keeps
    // the 3x3x3 neighborhood walk correct (every pair within r_cut still
    // lands in adjacent cells); bigger cells only cost extra distance
    // checks, degrading smoothly toward brute force instead of crashing.
    let budget = (4 * pos.len()).max(64) as f64;
    let mut cell = r_cut.max(1e-9);
    loop {
        let est: f64 = (0..3)
            .map(|k| ((hi[k] - lo[k]) / cell).floor() + 1.0)
            .product();
        if est <= budget || !est.is_finite() {
            break;
        }
        cell *= 2.0;
    }
    let dims: [usize; 3] = std::array::from_fn(|k| {
        (((hi[k] - lo[k]) / cell).floor() as usize + 1).max(1)
    });
    let cell_of = |p: &[f64; 3]| -> [usize; 3] {
        std::array::from_fn(|k| {
            (((p[k] - lo[k]) / cell).floor() as usize).min(dims[k] - 1)
        })
    };
    let idx = |c: [usize; 3]| (c[0] * dims[1] + c[1]) * dims[2] + c[2];
    let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); dims[0] * dims[1] * dims[2]];
    for (i, p) in pos.iter().enumerate() {
        buckets[idx(cell_of(p))].push(i);
    }
    let rc2 = r_cut * r_cut;
    let mut out = Vec::new();
    for (i, p) in pos.iter().enumerate() {
        let c = cell_of(p);
        for dx in -1i64..=1 {
            for dy in -1i64..=1 {
                for dz in -1i64..=1 {
                    let nc = [
                        c[0] as i64 + dx,
                        c[1] as i64 + dy,
                        c[2] as i64 + dz,
                    ];
                    if nc.iter().zip(&dims).any(|(v, d)| *v < 0 || *v >= *d as i64)
                    {
                        continue;
                    }
                    let b = idx([nc[0] as usize, nc[1] as usize, nc[2] as usize]);
                    for &j in &buckets[b] {
                        if j != i && dist2(*p, pos[j]) < rc2 {
                            out.push((i, j));
                        }
                    }
                }
            }
        }
    }
    out
}

#[inline]
fn dist2(a: [f64; 3], b: [f64; 3]) -> f64 {
    let d = [a[0] - b[0], a[1] - b[1], a[2] - b[2]];
    d[0] * d[0] + d[1] * d[1] + d[2] * d[2]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, PropConfig};

    #[test]
    fn brute_simple() {
        let pos = vec![[0.0, 0.0, 0.0], [1.0, 0.0, 0.0], [5.0, 0.0, 0.0]];
        let n = neighbors_brute(&pos, 2.0);
        assert_eq!(n, vec![(0, 1), (1, 0)]);
    }

    #[test]
    fn cell_matches_brute_property() {
        check("cell-list == brute-force", PropConfig { cases: 24, seed: 5 },
              |rng, case| {
            let n = 4 + case % 40;
            let pos: Vec<[f64; 3]> = (0..n)
                .map(|_| [rng.uniform(-3.0, 3.0), rng.uniform(-3.0, 3.0),
                          rng.uniform(-3.0, 3.0)])
                .collect();
            let rc = rng.uniform(0.5, 2.5);
            let mut a = neighbors_brute(&pos, rc);
            let mut b = neighbors_cell(&pos, rc);
            a.sort_unstable();
            b.sort_unstable();
            if a == b {
                Ok(())
            } else {
                Err(format!("mismatch: brute {} vs cell {}", a.len(), b.len()))
            }
        });
    }

    #[test]
    fn directed_symmetry() {
        let pos = vec![[0.0; 3], [0.5, 0.5, 0.5], [0.9, 0.0, 0.1]];
        let n = neighbors_cell(&pos, 1.5);
        for (i, j) in &n {
            assert!(n.contains(&(*j, *i)));
        }
    }

    #[test]
    fn empty_input() {
        assert!(neighbors_cell(&[], 1.0).is_empty());
    }

    #[test]
    fn sparse_extreme_extent_does_not_allocate_the_world() {
        // Pre-fix this asked for ((1e5/0.5)+1)^2 * 1 ≈ 4e10 buckets from
        // two atoms alone (and ~10^15 with a z extent too); now the cell
        // width grows until the grid fits the 4*n_atoms budget.
        let pos = vec![[0.0, 0.0, 0.0], [1.0e5, 1.0e5, 1.0e5]];
        assert!(neighbors_cell(&pos, 0.5).is_empty());

        // Same geometry, but with a close pair at each end: adjacency
        // must survive the cell-width growth.
        let pos = vec![
            [0.0, 0.0, 0.0],
            [0.3, 0.0, 0.0],
            [1.0e5, 1.0e5, 1.0e5],
            [1.0e5 + 0.3, 1.0e5, 1.0e5],
        ];
        let mut got = neighbors_cell(&pos, 0.5);
        got.sort_unstable();
        assert_eq!(got, vec![(0, 1), (1, 0), (2, 3), (3, 2)]);
    }

    #[test]
    fn sparse_clusters_match_brute_property() {
        // Widely separated dense clusters: the capped grid must agree
        // with brute force exactly.
        check(
            "sparse cell-list == brute-force",
            PropConfig { cases: 12, seed: 11 },
            |rng, case| {
                let clusters = 2 + case % 3;
                let mut pos = Vec::new();
                for c in 0..clusters {
                    let center = [
                        1.0e4 * c as f64,
                        rng.uniform(-1.0e3, 1.0e3),
                        rng.uniform(-1.0e3, 1.0e3),
                    ];
                    for _ in 0..(3 + case % 6) {
                        pos.push([
                            center[0] + rng.uniform(-1.0, 1.0),
                            center[1] + rng.uniform(-1.0, 1.0),
                            center[2] + rng.uniform(-1.0, 1.0),
                        ]);
                    }
                }
                let rc = rng.uniform(0.5, 2.0);
                let mut a = neighbors_brute(&pos, rc);
                let mut b = neighbors_cell(&pos, rc);
                a.sort_unstable();
                b.sort_unstable();
                if a == b {
                    Ok(())
                } else {
                    Err(format!(
                        "mismatch: brute {} vs cell {}",
                        a.len(),
                        b.len()
                    ))
                }
            },
        );
    }
}
