//! Time integration: velocity Verlet (NVE) and Langevin (BAOAB, NVT).
//!
//! Forces come either from a classical [`Potential`] ([`Integrator::step`])
//! or from any [`ForceProvider`] ([`Integrator::step_with`]) — in
//! particular [`crate::md::potential::LearnedPotential`], so the trained
//! Gaunt-engine model drives MD through the exact same BAOAB scheme as
//! the ground truth.

use super::potential::Potential;
use super::relax::ForceProvider;
use crate::util::rng::Rng;

/// Thermostat selection.
#[derive(Clone, Copy, Debug)]
pub enum Thermostat {
    /// Microcanonical (energy conserving).
    None,
    /// Langevin BAOAB with friction gamma and temperature T (k_B = 1).
    Langevin { gamma: f64, temperature: f64 },
}

/// MD state + integrator.
pub struct Integrator {
    pub pos: Vec<[f64; 3]>,
    pub vel: Vec<[f64; 3]>,
    pub species: Vec<usize>,
    pub mass: f64,
    pub dt: f64,
    pub thermostat: Thermostat,
    forces: Vec<[f64; 3]>,
    pub potential_energy: f64,
}

impl Integrator {
    pub fn new(
        pos: Vec<[f64; 3]>,
        species: Vec<usize>,
        pot: &Potential,
        dt: f64,
        thermostat: Thermostat,
    ) -> Self {
        let n = pos.len();
        let (e, f) = pot.energy_forces(&pos, &species);
        Integrator {
            pos,
            vel: vec![[0.0; 3]; n],
            species,
            mass: 1.0,
            dt,
            thermostat,
            forces: f,
            potential_energy: e,
        }
    }

    /// Draw Maxwell-Boltzmann velocities at temperature T.  No-op for
    /// an empty system (n = 0 must not reach the COM division below).
    pub fn thermalize(&mut self, temperature: f64, rng: &mut Rng) {
        if self.vel.is_empty() {
            return;
        }
        let s = (temperature / self.mass).sqrt();
        for v in self.vel.iter_mut() {
            for k in 0..3 {
                v[k] = s * rng.normal();
            }
        }
        self.remove_com_velocity();
    }

    fn remove_com_velocity(&mut self) {
        let n = self.vel.len() as f64;
        if n == 0.0 {
            return; // 0/0 would seed every velocity with NaN
        }
        let mut com = [0.0f64; 3];
        for v in &self.vel {
            for k in 0..3 {
                com[k] += v[k] / n;
            }
        }
        for v in self.vel.iter_mut() {
            for k in 0..3 {
                v[k] -= com[k];
            }
        }
    }

    /// Build the integrator with forces from an arbitrary provider
    /// (e.g. the learned potential).
    pub fn new_with<P: ForceProvider>(
        pos: Vec<[f64; 3]>,
        species: Vec<usize>,
        provider: &mut P,
        dt: f64,
        thermostat: Thermostat,
    ) -> Self {
        let n = pos.len();
        let (e, f) = provider.energy_forces(&pos);
        Integrator {
            pos,
            vel: vec![[0.0; 3]; n],
            species,
            mass: 1.0,
            dt,
            thermostat,
            forces: f,
            potential_energy: e,
        }
    }

    /// One BAOAB step with forces from an arbitrary [`ForceProvider`].
    pub fn step_with<P: ForceProvider>(
        &mut self, provider: &mut P, rng: &mut Rng,
    ) {
        let dt = self.dt;
        let m = self.mass;
        // B: half kick
        for (v, f) in self.vel.iter_mut().zip(&self.forces) {
            for k in 0..3 {
                v[k] += 0.5 * dt * f[k] / m;
            }
        }
        // A: half drift
        for (p, v) in self.pos.iter_mut().zip(&self.vel) {
            for k in 0..3 {
                p[k] += 0.5 * dt * v[k];
            }
        }
        // O: thermostat
        if let Thermostat::Langevin { gamma, temperature } = self.thermostat {
            let c1 = (-gamma * dt).exp();
            let c2 = ((1.0 - c1 * c1) * temperature / m).sqrt();
            for v in self.vel.iter_mut() {
                for vk in v.iter_mut() {
                    *vk = c1 * *vk + c2 * rng.normal();
                }
            }
        }
        // A: half drift
        for (p, v) in self.pos.iter_mut().zip(&self.vel) {
            for k in 0..3 {
                p[k] += 0.5 * dt * v[k];
            }
        }
        // force refresh + B: half kick
        let (e, f) = provider.energy_forces(&self.pos);
        self.potential_energy = e;
        self.forces = f;
        for (v, f) in self.vel.iter_mut().zip(&self.forces) {
            for k in 0..3 {
                v[k] += 0.5 * dt * f[k] / m;
            }
        }
    }

    /// Drive up to `steps` BAOAB steps from an arbitrary provider,
    /// invoking `on_frame(step, &self)` after each; returning `false`
    /// from the callback stops the rollout early (cooperative
    /// cancellation).  Returns the number of steps integrated.  This is
    /// the substrate of the coordinator's streaming `MdRollout` task.
    pub fn rollout_with<P, F>(
        &mut self, provider: &mut P, rng: &mut Rng, steps: usize,
        mut on_frame: F,
    ) -> usize
    where
        P: ForceProvider,
        F: FnMut(usize, &Integrator) -> bool,
    {
        for step in 0..steps {
            self.step_with(provider, rng);
            if !on_frame(step, self) {
                return step + 1;
            }
        }
        steps
    }

    /// One integration step with the classical potential.  Delegates to
    /// [`Integrator::step_with`] so classical and learned-potential MD
    /// share ONE BAOAB implementation (the species list is lent to the
    /// provider closure for the duration of the step; `step_with` never
    /// reads `self.species`).
    pub fn step(&mut self, pot: &Potential, rng: &mut Rng) {
        let species = std::mem::take(&mut self.species);
        let mut provider =
            |pos: &[[f64; 3]]| pot.energy_forces(pos, &species);
        self.step_with(&mut provider, rng);
        self.species = species;
    }

    pub fn kinetic_energy(&self) -> f64 {
        0.5 * self.mass
            * self
                .vel
                .iter()
                .map(|v| v[0] * v[0] + v[1] * v[1] + v[2] * v[2])
                .sum::<f64>()
    }

    /// Instantaneous temperature (k_B = 1): 2 KE / (3 N); 0 for an
    /// empty system instead of 0/0 = NaN.
    pub fn temperature(&self) -> f64 {
        if self.pos.is_empty() {
            return 0.0;
        }
        2.0 * self.kinetic_energy() / (3.0 * self.pos.len() as f64)
    }

    pub fn total_energy(&self) -> f64 {
        self.kinetic_energy() + self.potential_energy
    }

    pub fn forces(&self) -> &[[f64; 3]] {
        &self.forces
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::md::potential::Potential;

    fn lj_cluster(n_side: usize, spacing: f64) -> Vec<[f64; 3]> {
        let mut pos = Vec::new();
        for i in 0..n_side {
            for j in 0..n_side {
                for k in 0..n_side {
                    pos.push([i as f64 * spacing, j as f64 * spacing,
                              k as f64 * spacing]);
                }
            }
        }
        pos
    }

    #[test]
    fn step_with_provider_matches_classical_step() {
        let pot = Potential::lj(1.0, 1.0, 3.0);
        let pos = lj_cluster(2, 1.15);
        let species = vec![0usize; pos.len()];
        let mut rng_a = Rng::new(5);
        let mut rng_b = Rng::new(5);
        let mut md_a = Integrator::new(pos.clone(), species.clone(), &pot,
                                       0.003, Thermostat::None);
        let sp = species.clone();
        let p2 = pot.clone();
        let mut provider = move |x: &[[f64; 3]]| p2.energy_forces(x, &sp);
        let mut md_b = Integrator::new_with(pos, species, &mut provider,
                                            0.003, Thermostat::None);
        md_a.thermalize(0.1, &mut rng_a);
        md_b.thermalize(0.1, &mut rng_b);
        for _ in 0..50 {
            md_a.step(&pot, &mut rng_a);
            md_b.step_with(&mut provider, &mut rng_b);
        }
        assert_eq!(md_a.pos, md_b.pos);
        assert_eq!(md_a.vel, md_b.vel);
        assert_eq!(md_a.potential_energy, md_b.potential_energy);
    }

    #[test]
    fn rollout_with_matches_manual_stepping_and_stops_early() {
        let pot = Potential::lj(1.0, 1.0, 3.0);
        let pos = lj_cluster(2, 1.15);
        let species = vec![0usize; pos.len()];
        let sp = species.clone();
        let p2 = pot.clone();
        let mut provider = move |x: &[[f64; 3]]| p2.energy_forces(x, &sp);
        let mut rng_a = Rng::new(7);
        let mut rng_b = Rng::new(7);
        let mut md_a = Integrator::new_with(pos.clone(), species.clone(),
                                            &mut provider, 0.003,
                                            Thermostat::None);
        let mut md_b = Integrator::new_with(pos, species, &mut provider,
                                            0.003, Thermostat::None);
        let mut frames = 0usize;
        let done = md_a.rollout_with(&mut provider, &mut rng_a, 10,
                                     |_, _| { frames += 1; true });
        assert_eq!(done, 10);
        assert_eq!(frames, 10);
        for _ in 0..10 {
            md_b.step_with(&mut provider, &mut rng_b);
        }
        assert_eq!(md_a.pos, md_b.pos);
        assert_eq!(md_a.vel, md_b.vel);
        // early stop via the callback
        let done = md_b.rollout_with(&mut provider, &mut rng_b, 100,
                                     |step, _| step < 2);
        assert_eq!(done, 3, "stops after the callback returns false");
    }

    #[test]
    fn nve_conserves_energy() {
        let pot = Potential::lj(1.0, 1.0, 3.0);
        let pos = lj_cluster(2, 1.12);
        let species = vec![0; pos.len()];
        let mut rng = Rng::new(0);
        let mut md = Integrator::new(pos, species, &pot, 0.002, Thermostat::None);
        md.thermalize(0.1, &mut rng);
        let e0 = md.total_energy();
        for _ in 0..2000 {
            md.step(&pot, &mut rng);
        }
        let e1 = md.total_energy();
        let drift = (e1 - e0).abs() / e0.abs().max(1.0);
        assert!(drift < 1e-3, "NVE drift {drift}");
    }

    #[test]
    fn langevin_reaches_target_temperature() {
        let pot = Potential::lj(1.0, 1.0, 3.0);
        let pos = lj_cluster(2, 1.2);
        let species = vec![0; pos.len()];
        let mut rng = Rng::new(1);
        let target = 0.35;
        let mut md = Integrator::new(
            pos, species, &pot, 0.004,
            Thermostat::Langevin { gamma: 2.0, temperature: target },
        );
        md.thermalize(target, &mut rng);
        // equilibrate then average
        for _ in 0..2000 {
            md.step(&pot, &mut rng);
        }
        let mut t_acc = 0.0;
        let samples = 4000;
        for _ in 0..samples {
            md.step(&pot, &mut rng);
            t_acc += md.temperature();
        }
        let t_avg = t_acc / samples as f64;
        assert!(
            (t_avg - target).abs() < 0.12 * target + 0.05,
            "T_avg {t_avg} vs target {target}"
        );
    }

    #[test]
    fn thermalize_removes_com_motion() {
        let pot = Potential::lj(1.0, 1.0, 3.0);
        let pos = lj_cluster(2, 1.2);
        let mut rng = Rng::new(2);
        let mut md = Integrator::new(pos, vec![0; 8], &pot, 0.002,
                                     Thermostat::None);
        md.thermalize(1.0, &mut rng);
        for k in 0..3 {
            let s: f64 = md.vel.iter().map(|v| v[k]).sum();
            assert!(s.abs() < 1e-10);
        }
    }

    #[test]
    fn empty_system_is_nan_free() {
        let pot = Potential::lj(1.0, 1.0, 3.0);
        let mut rng = Rng::new(9);
        let mut md = Integrator::new(Vec::new(), Vec::new(), &pot, 0.002,
                                     Thermostat::None);
        // thermalize/remove_com used to hit 0/0 here and seed NaN
        md.thermalize(1.0, &mut rng);
        md.step(&pot, &mut rng);
        assert!(md.vel.is_empty() && md.pos.is_empty());
        assert_eq!(md.temperature(), 0.0);
        assert_eq!(md.kinetic_energy(), 0.0);
        assert!(md.total_energy().is_finite());
    }

    #[test]
    fn kinetic_energy_matches_temperature() {
        let pot = Potential::lj(1.0, 1.0, 3.0);
        let pos = lj_cluster(2, 1.2);
        let mut rng = Rng::new(3);
        let mut md = Integrator::new(pos, vec![0; 8], &pot, 0.002,
                                     Thermostat::None);
        md.thermalize(0.5, &mut rng);
        let t = md.temperature();
        assert!((t - 2.0 * md.kinetic_energy() / (3.0 * 8.0)).abs() < 1e-12);
    }
}
