//! Flexible-molecule builder — the "3BPA-lite" workload.
//!
//! 3BPA (3-(benzyloxy)pyridin-2-amine) is a flexible drug-like molecule
//! whose MD at rising temperatures explores increasingly strained
//! conformations.  We build a synthetic analog with the same *mechanical*
//! character: two rigid rings connected by a rotatable linker chain, with
//! harmonic bonds, a Morse backbone, and LJ nonbonded interactions —
//! enough structure that (a) low-T sampling stays near the basin and
//! (b) high-T sampling is genuinely out-of-distribution, reproducing the
//! 3BPA evaluation protocol (DESIGN.md §3).

use super::neighbor::Cell;
use super::potential::{Potential, PotentialKind};

/// A molecule: initial geometry + species + its potential.
#[derive(Clone, Debug)]
pub struct Molecule {
    pub pos: Vec<[f64; 3]>,
    pub species: Vec<usize>,
    pub potential: Potential,
}

impl Molecule {
    /// The synthetic flexible molecule ("3BPA-lite"): ring A (6 atoms,
    /// species 0) — linker chain (3 atoms, species 1) — ring B (5 atoms,
    /// species 2), 14 atoms total.
    pub fn bpa_lite() -> Molecule {
        let mut pos: Vec<[f64; 3]> = Vec::new();
        let mut species: Vec<usize> = Vec::new();
        let mut bonds: Vec<(usize, usize, PotentialKind)> = Vec::new();
        let ring_bond = |k: f64, r0: f64| PotentialKind::Harmonic { k, r0 };
        let backbone = PotentialKind::Morse { d: 3.0, a: 1.8, r0: 1.5 };

        // ring A: hexagon radius 1.4 in the xy-plane
        let ra = 1.4;
        for i in 0..6 {
            let ang = std::f64::consts::PI / 3.0 * i as f64;
            pos.push([ra * ang.cos(), ra * ang.sin(), 0.0]);
            species.push(0);
        }
        for i in 0..6 {
            bonds.push((i, (i + 1) % 6, ring_bond(60.0, 1.4)));
            // cross-brace to keep the ring rigid-ish
            bonds.push((i, (i + 2) % 6, ring_bond(15.0, 2.42)));
        }
        // linker chain: 3 atoms extending along +x
        let chain_start = pos.len();
        for i in 0..3 {
            pos.push([ra + 1.5 * (i + 1) as f64, 0.0, 0.2 * i as f64]);
            species.push(1);
        }
        bonds.push((0, chain_start, backbone));
        bonds.push((chain_start, chain_start + 1, backbone));
        bonds.push((chain_start + 1, chain_start + 2, backbone));
        // ring B: pentagon attached to the chain end, offset in z
        let rb = 1.2;
        let cx = ra + 4.5 + rb;
        let ring_b_start = pos.len();
        for i in 0..5 {
            let ang = 2.0 * std::f64::consts::PI / 5.0 * i as f64;
            pos.push([cx + rb * ang.cos(), rb * ang.sin(), 1.0]);
            species.push(2);
        }
        for i in 0..5 {
            bonds.push((
                ring_b_start + i,
                ring_b_start + (i + 1) % 5,
                ring_bond(60.0, 1.41),
            ));
            bonds.push((
                ring_b_start + i,
                ring_b_start + (i + 2) % 5,
                ring_bond(15.0, 2.28),
            ));
        }
        bonds.push((chain_start + 2, ring_b_start, backbone));

        // nonbonded: species-pair LJ table (3 species)
        let mut nonbonded = Vec::new();
        for s1 in 0..3usize {
            for s2 in 0..3usize {
                let sigma = 1.0 + 0.1 * (s1 + s2) as f64;
                let eps = 0.05 + 0.02 * ((s1 * s2) as f64);
                nonbonded.push(PotentialKind::LennardJones {
                    eps,
                    sigma,
                    r_cut: 4.0,
                });
            }
        }
        Molecule {
            pos,
            species,
            potential: Potential {
                n_species: 3,
                nonbonded,
                bonds,
                exclude_bonded_nonbonded: true,
            },
        }
    }

    /// Adsorbate-on-slab workload (the OC20-analog of Table 1): a small
    /// LJ molecule above a 2-layer crystalline slab, mixed species.
    pub fn adsorbate_slab(nx: usize, ny: usize, seed_offset: f64) -> Molecule {
        let mut pos = Vec::new();
        let mut species = Vec::new();
        let a = 1.3; // lattice constant
        for layer in 0..2usize {
            for i in 0..nx {
                for j in 0..ny {
                    let off = if layer == 1 { 0.5 * a } else { 0.0 };
                    pos.push([
                        i as f64 * a + off,
                        j as f64 * a + off,
                        -(layer as f64) * a,
                    ]);
                    species.push(layer); // species 0 = surface, 1 = subsurface
                }
            }
        }
        // adsorbate: 3-atom bent molecule above the center
        let cx = (nx - 1) as f64 * a / 2.0 + seed_offset;
        let cy = (ny - 1) as f64 * a / 2.0;
        let ads = [
            [cx, cy, 1.6],
            [cx + 1.1, cy, 2.1],
            [cx - 0.6, cy + 0.9, 2.2],
        ];
        let base = pos.len();
        for p in ads {
            pos.push(p);
            species.push(2);
        }
        let mut bonds = vec![
            (base, base + 1, PotentialKind::Morse { d: 4.0, a: 2.0, r0: 1.2 }),
            (base, base + 2, PotentialKind::Morse { d: 4.0, a: 2.0, r0: 1.2 }),
        ];
        // pin the slab lightly to its lattice sites via bonds to neighbors
        for i in 0..(2 * nx * ny) {
            if i + 1 < 2 * nx * ny {
                bonds.push((i, i + 1, PotentialKind::Harmonic { k: 8.0, r0: a }));
            }
        }
        let mut nonbonded = Vec::new();
        for s1 in 0..4usize {
            for s2 in 0..4usize {
                nonbonded.push(PotentialKind::LennardJones {
                    eps: 0.08 + 0.05 * ((s1 + s2) % 3) as f64,
                    sigma: 1.1 + 0.05 * ((s1 * s2) % 2) as f64,
                    r_cut: 3.5,
                });
            }
        }
        Molecule {
            pos,
            species,
            potential: Potential {
                n_species: 4,
                nonbonded,
                bonds,
                exclude_bonded_nonbonded: true,
            },
        }
    }

    /// Periodic bulk+adsorbate slab, the OCP-analog workload under real
    /// boundary conditions: an `nx x ny` two-layer crystalline slab
    /// periodic in x/y (the cell is commensurate with the lattice, so
    /// the surface is seamless across images), vacuum above, and a
    /// 3-atom adsorbate.  Returns the molecule plus its [`Cell`].
    pub fn periodic_slab(nx: usize, ny: usize) -> (Molecule, Cell) {
        assert!(nx >= 2 && ny >= 2, "periodic_slab: need at least 2x2");
        let a = 1.3; // lattice constant
        let lx = nx as f64 * a;
        let ly = ny as f64 * a;
        let lz = 12.0 * a; // slab + vacuum gap along z
        let cell = Cell::orthorhombic(lx, ly, lz);
        let mut pos = Vec::new();
        let mut species = Vec::new();
        for layer in 0..2usize {
            for i in 0..nx {
                for j in 0..ny {
                    let off = if layer == 1 { 0.5 * a } else { 0.0 };
                    pos.push([
                        i as f64 * a + off,
                        j as f64 * a + off,
                        2.0 * a - layer as f64 * a,
                    ]);
                    species.push(layer);
                }
            }
        }
        // adsorbate above the slab center
        let cx = lx / 2.0;
        let cy = ly / 2.0;
        let z0 = 2.0 * a + 1.6;
        let base = pos.len();
        for p in [
            [cx, cy, z0],
            [cx + 1.1, cy, z0 + 0.5],
            [cx - 0.6, cy + 0.9, z0 + 0.6],
        ] {
            pos.push(p);
            species.push(2);
        }
        let bonds = vec![
            (base, base + 1, PotentialKind::Morse { d: 4.0, a: 2.0, r0: 1.2 }),
            (base, base + 2, PotentialKind::Morse { d: 4.0, a: 2.0, r0: 1.2 }),
        ];
        // nonbonded LJ table, 3 species; cutoff must respect the
        // minimum-image bound min(lx, ly, lz) / 2 for small slabs
        let r_cut = 2.6f64.min(0.45 * lx.min(ly));
        let mut nonbonded = Vec::new();
        for s1 in 0..3usize {
            for s2 in 0..3usize {
                nonbonded.push(PotentialKind::LennardJones {
                    eps: 0.08 + 0.05 * ((s1 + s2) % 3) as f64,
                    sigma: 1.1,
                    r_cut,
                });
            }
        }
        let m = Molecule {
            pos,
            species,
            potential: Potential {
                n_species: 3,
                nonbonded,
                bonds,
                exclude_bonded_nonbonded: true,
            },
        };
        (m, cell)
    }

    /// Homogeneous periodic LJ box at reduced density `rho`: `n_side`^3
    /// atoms on a simple cubic lattice inside a cubic [`Cell`] — the
    /// standard large-system benchmark fill (10^5 atoms = `n_side` 47).
    pub fn lj_box(n_side: usize, rho: f64, r_cut: f64) -> (Molecule, Cell) {
        assert!(n_side >= 1 && rho > 0.0);
        let n = n_side * n_side * n_side;
        let l = (n as f64 / rho).cbrt();
        let cell = Cell::cubic(l);
        assert!(
            r_cut <= cell.max_cutoff(),
            "lj_box: r_cut {r_cut} exceeds minimum-image bound {}",
            cell.max_cutoff()
        );
        let spacing = l / n_side as f64;
        let mut pos = Vec::with_capacity(n);
        for i in 0..n_side {
            for j in 0..n_side {
                for k in 0..n_side {
                    pos.push([
                        (i as f64 + 0.5) * spacing,
                        (j as f64 + 0.5) * spacing,
                        (k as f64 + 0.5) * spacing,
                    ]);
                }
            }
        }
        let m = Molecule {
            pos,
            species: vec![0; n],
            potential: Potential::lj(1.0, 1.0, r_cut),
        };
        (m, cell)
    }

    pub fn n_atoms(&self) -> usize {
        self.pos.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::md::integrator::{Integrator, Thermostat};
    use crate::util::rng::Rng;

    #[test]
    fn bpa_lite_geometry() {
        let m = Molecule::bpa_lite();
        assert_eq!(m.n_atoms(), 14);
        assert_eq!(m.species.len(), 14);
        assert!(m.potential.bonds.len() > 20);
        // three species present
        for s in 0..3 {
            assert!(m.species.contains(&s));
        }
    }

    #[test]
    fn bpa_lite_is_stable_at_low_t() {
        // the molecule should not fly apart in a short low-T run
        let m = Molecule::bpa_lite();
        let mut rng = Rng::new(0);
        let mut md = Integrator::new(
            m.pos.clone(), m.species.clone(), &m.potential, 0.002,
            Thermostat::Langevin { gamma: 1.0, temperature: 0.05 },
        );
        md.thermalize(0.05, &mut rng);
        for _ in 0..2000 {
            md.step(&m.potential, &mut rng);
        }
        // max pair distance stays bounded (molecule intact)
        let mut max_d = 0.0f64;
        for i in 0..md.pos.len() {
            for j in 0..md.pos.len() {
                let d = [
                    md.pos[i][0] - md.pos[j][0],
                    md.pos[i][1] - md.pos[j][1],
                    md.pos[i][2] - md.pos[j][2],
                ];
                max_d = max_d.max(
                    (d[0] * d[0] + d[1] * d[1] + d[2] * d[2]).sqrt(),
                );
            }
        }
        assert!(max_d < 20.0, "molecule exploded: span {max_d}");
    }

    #[test]
    fn higher_temperature_explores_more() {
        // variance of positions at high T > low T (the OOD premise)
        let m = Molecule::bpa_lite();
        let spread = |temp: f64| -> f64 {
            let mut rng = Rng::new(7);
            let mut md = Integrator::new(
                m.pos.clone(), m.species.clone(), &m.potential, 0.002,
                Thermostat::Langevin { gamma: 1.0, temperature: temp },
            );
            md.thermalize(temp, &mut rng);
            let mut acc = 0.0;
            let mut count = 0;
            for step in 0..3000 {
                md.step(&m.potential, &mut rng);
                if step > 500 && step % 50 == 0 {
                    // RMS displacement from the initial geometry
                    let mut d2 = 0.0;
                    for (p, q) in md.pos.iter().zip(&m.pos) {
                        for k in 0..3 {
                            d2 += (p[k] - q[k]) * (p[k] - q[k]);
                        }
                    }
                    acc += (d2 / md.pos.len() as f64).sqrt();
                    count += 1;
                }
            }
            acc / count as f64
        };
        let lo = spread(0.02);
        let hi = spread(0.3);
        assert!(hi > lo, "high-T spread {hi} <= low-T {lo}");
    }

    #[test]
    fn adsorbate_slab_shapes() {
        let m = Molecule::adsorbate_slab(3, 3, 0.0);
        assert_eq!(m.n_atoms(), 2 * 9 + 3);
        assert_eq!(*m.species.iter().max().unwrap(), 2);
        let (e, f) = m.potential.energy_forces(&m.pos, &m.species);
        assert!(e.is_finite());
        assert!(f.iter().all(|v| v.iter().all(|x| x.is_finite())));
    }

    #[test]
    fn periodic_slab_is_consistent_with_its_cell() {
        let (m, cell) = Molecule::periodic_slab(4, 4);
        assert_eq!(m.n_atoms(), 2 * 16 + 3);
        // cutoff respects the minimum-image bound
        let rc = m.potential.nonbonded_cutoff().unwrap();
        assert!(rc <= cell.max_cutoff());
        // every atom sits inside the cell footprint in x/y
        let l = cell.lattice();
        for p in &m.pos {
            assert!(p[0] > -1e-9 && p[0] < l[0][0] + 1e-9);
            assert!(p[1] > -1e-9 && p[1] < l[1][1] + 1e-9);
        }
        let (e, f) =
            m.potential.energy_forces_periodic(&m.pos, &m.species, &cell);
        assert!(e.is_finite());
        for k in 0..3 {
            let s: f64 = f.iter().map(|v| v[k]).sum();
            assert!(s.abs() < 1e-9, "net periodic force along {k}: {s}");
        }
    }

    #[test]
    fn lj_box_fills_the_cell() {
        let (m, cell) = Molecule::lj_box(5, 0.8, 2.5);
        assert_eq!(m.n_atoms(), 125);
        let l = cell.lattice()[0][0];
        assert!((l - (125.0f64 / 0.8).cbrt()).abs() < 1e-12);
        for p in &m.pos {
            for k in 0..3 {
                assert!(p[k] > 0.0 && p[k] < l);
            }
        }
        // lattice fill is a force-free configuration by symmetry
        let (_, f) =
            m.potential.energy_forces_periodic(&m.pos, &m.species, &cell);
        for v in &f {
            for x in v {
                assert!(x.abs() < 1e-9, "lattice fill not force-free");
            }
        }
    }
}
