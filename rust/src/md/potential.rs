//! Potentials with analytic forces.
//!
//! * Classical terms (Lennard-Jones, Morse, harmonic bonds) — the
//!   ground-truth label generators for the synthetic OC20/3BPA-analog
//!   datasets.
//! * [`LearnedPotential`] — the trained Gaunt-engine [`Model`] wrapped
//!   as a force provider, so `md::relax` (FIRE) and
//!   `md::integrator` drive the REAL learned force field exactly like
//!   the classical one.
//! * [`SystemPotential`] — the closed enum over both, letting drivers
//!   switch ground truth <-> learned model with one constructor.

use std::sync::Arc;

use crate::model::{Model, ModelScratch};
use super::neighbor::{neighbors_cell, neighbors_periodic_cell, Cell,
                      VerletList};
use super::relax::ForceProvider;

/// Pairwise potential kinds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PotentialKind {
    /// 4 eps ((s/r)^12 - (s/r)^6) in shifted-force form: energy AND
    /// dE/dr both reach zero at r_cut (C^1 cutoff).
    LennardJones { eps: f64, sigma: f64, r_cut: f64 },
    /// D (1 - e^{-a(r - r0)})^2 - D.
    Morse { d: f64, a: f64, r0: f64 },
    /// (k/2)(r - r0)^2 (used for bonded terms).
    Harmonic { k: f64, r0: f64 },
}

impl PotentialKind {
    /// (energy, dE/dr) at scalar distance r.
    pub fn energy_deriv(&self, r: f64) -> (f64, f64) {
        match *self {
            PotentialKind::LennardJones { eps, sigma, r_cut } => {
                if r >= r_cut {
                    return (0.0, 0.0);
                }
                let sr6 = (sigma / r).powi(6);
                let sr12 = sr6 * sr6;
                // Shifted-force form: e' = e - e_c - (r - r_cut) de_c,
                // de' = de - de_c, so BOTH vanish at the cutoff.  The
                // previous energy-only shift left dE/dr jumping by de_c
                // at r_cut — a force discontinuity that injected energy
                // every time a pair crossed the cutoff and drifted NVE
                // trajectories.
                let src6 = (sigma / r_cut).powi(6);
                let src12 = src6 * src6;
                let e_cut = 4.0 * eps * (src12 - src6);
                let de_cut =
                    4.0 * eps * (-12.0 * src12 + 6.0 * src6) / r_cut;
                let e = 4.0 * eps * (sr12 - sr6) - e_cut
                    - (r - r_cut) * de_cut;
                let de = 4.0 * eps * (-12.0 * sr12 + 6.0 * sr6) / r
                    - de_cut;
                (e, de)
            }
            PotentialKind::Morse { d, a, r0 } => {
                let x = (-a * (r - r0)).exp();
                let e = d * (1.0 - x) * (1.0 - x) - d;
                let de = 2.0 * d * a * (1.0 - x) * x;
                (e, de)
            }
            PotentialKind::Harmonic { k, r0 } => {
                let e = 0.5 * k * (r - r0) * (r - r0);
                let de = k * (r - r0);
                (e, de)
            }
        }
    }

    /// Interaction cutoff, if this kind has one (Morse/Harmonic do
    /// not, so tables containing them cannot route through a
    /// cutoff-radius neighbor list).
    pub fn cutoff(&self) -> Option<f64> {
        match *self {
            PotentialKind::LennardJones { r_cut, .. } => Some(r_cut),
            _ => None,
        }
    }
}

/// Accumulate one pair term with displacement `d = r_i - r_j` (+ image
/// shift under PBC): `E += e(r)`, `F_i += -dE/dr * d / r`, `F_j -=` the
/// same (Newton's third law is exact per pair).
#[inline]
fn accumulate_pair(
    kind: &PotentialKind, d: [f64; 3], i: usize, j: usize,
    e: &mut f64, f: &mut [[f64; 3]],
) {
    let r = (d[0] * d[0] + d[1] * d[1] + d[2] * d[2]).sqrt().max(1e-9);
    let (pe, de) = kind.energy_deriv(r);
    *e += pe;
    let s = -de / r;
    for k in 0..3 {
        f[i][k] += s * d[k];
        f[j][k] -= s * d[k];
    }
}

#[inline]
fn sub(a: [f64; 3], b: [f64; 3]) -> [f64; 3] {
    [a[0] - b[0], a[1] - b[1], a[2] - b[2]]
}

/// A full system potential: per-species-pair nonbonded terms + explicit
/// bonded terms.
#[derive(Clone, Debug)]
pub struct Potential {
    pub n_species: usize,
    /// Species-pair table, read through the SYMMETRIZED lookup
    /// [`Potential::pair_kind`]: `(s1, s2)` and `(s2, s1)` resolve to
    /// the same entry, so energies cannot depend on atom ordering even
    /// when the raw table is asymmetric.
    pub nonbonded: Vec<PotentialKind>,
    /// (i, j, kind) explicit bonds (applied in addition to nonbonded)
    pub bonds: Vec<(usize, usize, PotentialKind)>,
    /// bonded pairs excluded from nonbonded interactions
    pub exclude_bonded_nonbonded: bool,
}

impl Potential {
    /// Homogeneous LJ for quick tests.
    pub fn lj(eps: f64, sigma: f64, r_cut: f64) -> Self {
        Potential {
            n_species: 1,
            nonbonded: vec![PotentialKind::LennardJones { eps, sigma, r_cut }],
            bonds: Vec::new(),
            exclude_bonded_nonbonded: false,
        }
    }

    /// Symmetrized species-pair lookup (canonical min/max order).  The
    /// old `nonbonded[s_i * n + s_j]` read the table only in `i < j`
    /// atom order, so an asymmetric table silently made the energy a
    /// function of atom indexing.
    #[inline]
    pub fn pair_kind(&self, si: usize, sj: usize) -> PotentialKind {
        let (a, b) = if si <= sj { (si, sj) } else { (sj, si) };
        self.nonbonded[a * self.n_species + b]
    }

    /// Largest nonbonded cutoff, provided EVERY nonbonded kind has one
    /// — the precondition for routing nonbonded terms through a
    /// cutoff-radius neighbor list.  `None` (a cutoff-free kind in the
    /// table) falls back to the all-pairs loop.
    pub fn nonbonded_cutoff(&self) -> Option<f64> {
        let mut rc = 0.0f64;
        for k in &self.nonbonded {
            rc = rc.max(k.cutoff()?);
        }
        if rc > 0.0 { Some(rc) } else { None }
    }

    /// Normalized sorted bonded-pair set for O(log B) exclusion checks
    /// — the old `is_bonded` linearly scanned the bond list inside the
    /// O(N^2) pair loop (O(N^2 B)).  Returns an unallocated empty Vec
    /// when exclusions are off.  One-shot evaluators build it per call;
    /// trajectory drivers (e.g. [`PeriodicPotential`]) compute it once
    /// and feed [`Potential::energy_forces_with_list_excl`], keeping
    /// reuse steps allocation-free for bonded systems too.
    pub fn excluded_pairs(&self) -> Vec<(usize, usize)> {
        if !self.exclude_bonded_nonbonded || self.bonds.is_empty() {
            return Vec::new();
        }
        let mut ex: Vec<(usize, usize)> = self
            .bonds
            .iter()
            .map(|&(a, b, _)| (a.min(b), a.max(b)))
            .collect();
        ex.sort_unstable();
        ex.dedup();
        ex
    }

    /// Total energy + forces (open boundary).  `species[i]` indexes the
    /// nonbonded table (symmetrized).  Nonbonded terms route through
    /// the O(N) cell-list neighbor search whenever every kind carries a
    /// cutoff; pairs beyond it contribute exactly zero, so the result
    /// matches the all-pairs loop.
    pub fn energy_forces(&self, pos: &[[f64; 3]], species: &[usize])
        -> (f64, Vec<[f64; 3]>) {
        let n = pos.len();
        let mut e = 0.0;
        let mut f = vec![[0.0f64; 3]; n];
        let excl = self.excluded_pairs();
        let excluded = |i: usize, j: usize| {
            !excl.is_empty() && excl.binary_search(&(i, j)).is_ok()
        };
        match self.nonbonded_cutoff() {
            Some(rc) => {
                for (i, j) in neighbors_cell(pos, rc) {
                    if i < j && !excluded(i, j) {
                        let kind = self.pair_kind(species[i], species[j]);
                        accumulate_pair(&kind, sub(pos[i], pos[j]), i, j,
                                        &mut e, &mut f);
                    }
                }
            }
            None => {
                for i in 0..n {
                    for j in (i + 1)..n {
                        if excluded(i, j) {
                            continue;
                        }
                        let kind = self.pair_kind(species[i], species[j]);
                        accumulate_pair(&kind, sub(pos[i], pos[j]), i, j,
                                        &mut e, &mut f);
                    }
                }
            }
        }
        for (i, j, kind) in &self.bonds {
            accumulate_pair(kind, sub(pos[*i], pos[*j]), *i, *j,
                            &mut e, &mut f);
        }
        (e, f)
    }

    /// Periodic energy + forces under the minimum-image convention:
    /// nonbonded terms through the periodic cell list, bonded terms
    /// through minimum-image displacements.  Every nonbonded kind must
    /// carry a cutoff, and that cutoff must respect
    /// [`Cell::max_cutoff`] (asserted by the builder).
    pub fn energy_forces_periodic(
        &self, pos: &[[f64; 3]], species: &[usize], cell: &Cell,
    ) -> (f64, Vec<[f64; 3]>) {
        let rc = self.nonbonded_cutoff().expect(
            "energy_forces_periodic: every nonbonded kind needs a cutoff",
        );
        let mut e = 0.0;
        let mut f = vec![[0.0f64; 3]; pos.len()];
        let excl = self.excluded_pairs();
        for edge in neighbors_periodic_cell(pos, cell, rc) {
            let (i, j) = (edge.i, edge.j);
            if i < j
                && (excl.is_empty()
                    || excl.binary_search(&(i, j)).is_err())
            {
                let kind = self.pair_kind(species[i], species[j]);
                let sv = cell.shift_vector(edge.shift);
                let d = [
                    pos[i][0] - pos[j][0] + sv[0],
                    pos[i][1] - pos[j][1] + sv[1],
                    pos[i][2] - pos[j][2] + sv[2],
                ];
                accumulate_pair(&kind, d, i, j, &mut e, &mut f);
            }
        }
        for (i, j, kind) in &self.bonds {
            let (d, _) = cell.min_image(sub(pos[*i], pos[*j]));
            accumulate_pair(kind, d, *i, *j, &mut e, &mut f);
        }
        (e, f)
    }

    /// Energy + forces through a caller-owned [`VerletList`] — the
    /// large-system rollout hot path (open or periodic, per the list).
    /// Rebuilds the bonded-exclusion set each call; trajectory loops
    /// should precompute it once and use
    /// [`Potential::energy_forces_with_list_excl`] directly.
    pub fn energy_forces_with_list(
        &self, pos: &[[f64; 3]], species: &[usize], list: &mut VerletList,
        forces: &mut Vec<[f64; 3]>,
    ) -> f64 {
        let excl = self.excluded_pairs();
        self.energy_forces_with_list_excl(pos, species, list, forces, &excl)
    }

    /// [`Potential::energy_forces_with_list`] with a caller-supplied
    /// exclusion set (sorted canonical `(min, max)` pairs, as returned
    /// by [`Potential::excluded_pairs`]).  `forces` is cleared and
    /// refilled in place; once buffers are warm a reuse step (`update`
    /// returning false) performs zero allocations — including for
    /// bonded systems, since the exclusion set is reused (gated by
    /// `tests/alloc_regression.rs`).
    pub fn energy_forces_with_list_excl(
        &self, pos: &[[f64; 3]], species: &[usize], list: &mut VerletList,
        forces: &mut Vec<[f64; 3]>, excl: &[(usize, usize)],
    ) -> f64 {
        let rc = self.nonbonded_cutoff().expect(
            "energy_forces_with_list: every nonbonded kind needs a cutoff",
        );
        assert!(
            rc <= list.r_cut + 1e-12,
            "Verlet list cutoff {} below potential cutoff {rc}",
            list.r_cut
        );
        list.update(pos);
        forces.clear();
        forces.resize(pos.len(), [0.0; 3]);
        let mut e = 0.0;
        list.for_each_pair(pos, |i, j, d, _r2| {
            if excl.is_empty() || excl.binary_search(&(i, j)).is_err() {
                let kind = self.pair_kind(species[i], species[j]);
                accumulate_pair(&kind, d, i, j, &mut e, forces);
            }
        });
        for (i, j, kind) in &self.bonds {
            let d = sub(pos[*i], pos[*j]);
            let d = match list.cell() {
                Some(cell) => cell.min_image(d).0,
                None => d,
            };
            accumulate_pair(kind, d, *i, *j, &mut e, forces);
        }
        e
    }
}

/// A classical potential bound to a periodic [`Cell`] and a
/// skin-buffered [`VerletList`] — the rollout-ready [`ForceProvider`]
/// for periodic MD.  Repeated evaluations reuse the neighbor list while
/// every atom stays within `skin / 2` of its build position;
/// [`PeriodicPotential::energy_forces_ref`] additionally reuses the
/// retained force buffer, making reuse steps allocation-free.
pub struct PeriodicPotential {
    pub potential: Potential,
    pub species: Vec<usize>,
    list: VerletList,
    forces: Vec<[f64; 3]>,
    /// Bonded-exclusion set, captured once at construction (bond
    /// topology is fixed along a trajectory) so reuse steps never
    /// re-sort it.
    excl: Vec<(usize, usize)>,
}

impl PeriodicPotential {
    /// `skin` buffers rebuilds; `r_cut + skin` must respect the cell's
    /// minimum-image bound (asserted by [`VerletList::periodic`]).
    /// The bonded-exclusion set is snapshotted here — mutate
    /// `potential.bonds` only through a fresh `PeriodicPotential`.
    pub fn new(
        potential: Potential, species: Vec<usize>, cell: Cell, skin: f64,
    ) -> PeriodicPotential {
        let rc = potential.nonbonded_cutoff().expect(
            "PeriodicPotential: every nonbonded kind needs a cutoff",
        );
        let excl = potential.excluded_pairs();
        PeriodicPotential {
            potential,
            species,
            list: VerletList::periodic(cell, rc, skin),
            forces: Vec::new(),
            excl,
        }
    }

    /// Energy + borrowed forces (the allocation-free reuse path).
    pub fn energy_forces_ref(
        &mut self, pos: &[[f64; 3]],
    ) -> (f64, &[[f64; 3]]) {
        let e = self.potential.energy_forces_with_list_excl(
            pos, &self.species, &mut self.list, &mut self.forces,
            &self.excl,
        );
        (e, &self.forces)
    }

    /// The underlying Verlet list (rebuild/reuse counters, cell).
    pub fn list(&self) -> &VerletList {
        &self.list
    }
}

impl ForceProvider for PeriodicPotential {
    fn energy_forces(&mut self, pos: &[[f64; 3]]) -> (f64, Vec<[f64; 3]>) {
        let (e, f) = self.energy_forces_ref(pos);
        (e, f.to_vec())
    }
}

/// The trained model as an MD/relaxation force provider: owns its
/// species assignment, scratch, and reusable force buffer, so repeated
/// evaluations along a trajectory reuse one workspace.
pub struct LearnedPotential {
    pub model: Arc<Model>,
    pub species: Vec<usize>,
    scratch: ModelScratch,
    forces_flat: Vec<f64>,
}

impl LearnedPotential {
    pub fn new(model: Arc<Model>, species: Vec<usize>) -> LearnedPotential {
        assert!(species.len() <= model.cfg.max_atoms);
        let scratch = model.scratch();
        let forces_flat = vec![0.0; 3 * species.len()];
        LearnedPotential { model, species, scratch, forces_flat }
    }

    /// Energy + forces at `pos` (neighbor list rebuilt per call; the
    /// model evaluation itself reuses the held scratch).
    pub fn compute(&mut self, pos: &[[f64; 3]]) -> (f64, Vec<[f64; 3]>) {
        assert_eq!(pos.len(), self.species.len());
        let edges = self.model.build_edges(pos);
        let e = self.model.energy_forces_into(
            pos, &self.species, &edges, &mut self.forces_flat,
            &mut self.scratch,
        );
        let forces = self
            .forces_flat
            .chunks_exact(3)
            .map(|c| [c[0], c[1], c[2]])
            .collect();
        (e, forces)
    }
}

impl ForceProvider for LearnedPotential {
    fn energy_forces(&mut self, pos: &[[f64; 3]]) -> (f64, Vec<[f64; 3]>) {
        self.compute(pos)
    }
}

/// Either force field behind one façade: ground-truth classical terms or
/// the served/learned Gaunt model.  Implements [`ForceProvider`], so
/// FIRE relaxation and the MD integrator run identically on both.
pub enum SystemPotential {
    Classical { potential: Potential, species: Vec<usize> },
    Learned(LearnedPotential),
}

impl SystemPotential {
    pub fn classical(potential: Potential, species: Vec<usize>)
        -> SystemPotential {
        SystemPotential::Classical { potential, species }
    }

    pub fn learned(model: Arc<Model>, species: Vec<usize>)
        -> SystemPotential {
        SystemPotential::Learned(LearnedPotential::new(model, species))
    }

    pub fn compute(&mut self, pos: &[[f64; 3]]) -> (f64, Vec<[f64; 3]>) {
        match self {
            SystemPotential::Classical { potential, species } => {
                potential.energy_forces(pos, species)
            }
            SystemPotential::Learned(lp) => lp.compute(pos),
        }
    }
}

impl ForceProvider for SystemPotential {
    fn energy_forces(&mut self, pos: &[[f64; 3]]) -> (f64, Vec<[f64; 3]>) {
        self.compute(pos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn learned_potential_drives_fire_and_matches_model() {
        use crate::md::relax::{fire_relax, FireConfig};
        use crate::model::ModelConfig;
        let model = Arc::new(Model::new(
            ModelConfig { n_layers: 1, ..Default::default() }, 5));
        let species = vec![0usize, 1, 2, 0];
        let mut rng = Rng::new(2);
        let pos: Vec<[f64; 3]> = (0..4)
            .map(|_| [rng.normal(), rng.normal(), rng.normal()])
            .collect();
        let mut lp = LearnedPotential::new(model.clone(), species.clone());
        let (e, f) = lp.compute(&pos);
        let (e2, f2) = model.energy_forces(&pos, &species);
        assert_eq!(e, e2);
        assert_eq!(f, f2);
        // a few FIRE steps through the provider must run and stay finite
        let mut sys = SystemPotential::learned(model, species);
        let res = fire_relax(&mut sys, &pos,
                             FireConfig { max_steps: 5, ..Default::default() });
        assert!(res.energy.is_finite());
        assert_eq!(res.energy_trace.len(), res.steps + 1);
    }

    #[test]
    fn system_potential_classical_matches_direct() {
        let pot = Potential::lj(1.0, 1.0, 5.0);
        let species = vec![0usize; 3];
        let pos = vec![[0.0; 3], [1.2, 0.0, 0.0], [0.0, 1.3, 0.0]];
        let (e, f) = pot.energy_forces(&pos, &species);
        let mut sys = SystemPotential::classical(pot, species);
        let (e2, f2) = sys.compute(&pos);
        assert_eq!(e, e2);
        assert_eq!(f, f2);
    }

    #[test]
    fn lj_minimum_at_r_min() {
        let p = PotentialKind::LennardJones { eps: 1.0, sigma: 1.0, r_cut: 10.0 };
        let r_min = 2f64.powf(1.0 / 6.0);
        // the shifted-force term tilts the well by -de_cut (~2.4e-6 at
        // r_cut = 10), so the stationary point moves by that much
        let (_, d) = p.energy_deriv(r_min);
        assert!(d.abs() < 1e-5);
        let (e, _) = p.energy_deriv(r_min);
        assert!((e + 1.0).abs() < 1e-3); // ~ -eps (small cutoff shift)
    }

    #[test]
    fn lj_cutoff_continuous() {
        let p = PotentialKind::LennardJones { eps: 1.0, sigma: 1.0, r_cut: 2.5 };
        let (e_in, de_in) = p.energy_deriv(2.5 - 1e-7);
        let (e_out, de_out) = p.energy_deriv(2.5 + 1e-7);
        // shifted-force: BOTH energy and dE/dr are continuous (-> 0) at
        // the cutoff.  The old energy-only shift left dE/dr jumping by
        // ~ -0.039 here.
        assert!(e_in.abs() < 1e-6 && e_out == 0.0);
        assert!(de_in.abs() < 1e-5 && de_out == 0.0, "force jump at r_cut: {de_in}");
    }

    #[test]
    fn lj_energy_and_force_vanish_smoothly_at_cutoff() {
        // approach the cutoff from inside: |e| and |dE/dr| both shrink
        // like (r_cut - r) and (r_cut - r) respectively
        let p = PotentialKind::LennardJones { eps: 0.7, sigma: 1.1, r_cut: 3.0 };
        let (e1, d1) = p.energy_deriv(3.0 - 1e-3);
        let (e2, d2) = p.energy_deriv(3.0 - 1e-4);
        assert!(e2.abs() < e1.abs() && d2.abs() < d1.abs());
        assert!(d2.abs() < 1e-2 * (1.0 + d1.abs()));
    }

    #[test]
    fn asymmetric_table_is_permutation_invariant() {
        // deliberately asymmetric raw table: entry (0,1) != entry (1,0)
        let lj_a = PotentialKind::LennardJones { eps: 1.0, sigma: 1.0, r_cut: 4.0 };
        let lj_b = PotentialKind::LennardJones { eps: 0.25, sigma: 1.3, r_cut: 4.0 };
        let lj_x = PotentialKind::LennardJones { eps: 2.0, sigma: 0.9, r_cut: 4.0 };
        let pot = Potential {
            n_species: 2,
            nonbonded: vec![lj_a, lj_x, lj_b, lj_a],
            bonds: Vec::new(),
            exclude_bonded_nonbonded: false,
        };
        // symmetrized lookup: (0,1) and (1,0) must agree
        assert_eq!(pot.pair_kind(0, 1), pot.pair_kind(1, 0));
        let pos = vec![[0.0, 0.0, 0.0], [1.4, 0.0, 0.0], [0.3, 1.5, 0.2]];
        let species = [0usize, 1, 0];
        let (e, f) = pot.energy_forces(&pos, &species);
        // reverse the atom order: energy identical, forces permuted
        let rpos: Vec<[f64; 3]> = pos.iter().rev().copied().collect();
        let rspecies: Vec<usize> = species.iter().rev().copied().collect();
        let (er, fr) = pot.energy_forces(&rpos, &rspecies);
        assert!((e - er).abs() < 1e-12, "{e} vs {er}");
        for i in 0..3 {
            for k in 0..3 {
                assert!((f[i][k] - fr[2 - i][k]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn cell_list_route_matches_all_pairs_reference() {
        // same potential evaluated via the neighbor-list route and via a
        // manual all-pairs double loop must agree exactly
        let mut rng = Rng::new(7);
        let pot = Potential::lj(1.0, 1.0, 2.5);
        let n = 40;
        let pos: Vec<[f64; 3]> = (0..n)
            .map(|_| [rng.uniform(0.0, 6.0), rng.uniform(0.0, 6.0),
                      rng.uniform(0.0, 6.0)])
            .collect();
        let species = vec![0usize; n];
        let (e, f) = pot.energy_forces(&pos, &species);
        let mut e_ref = 0.0;
        let mut f_ref = vec![[0.0f64; 3]; n];
        for i in 0..n {
            for j in (i + 1)..n {
                let kind = pot.pair_kind(species[i], species[j]);
                accumulate_pair(&kind, sub(pos[i], pos[j]), i, j,
                                &mut e_ref, &mut f_ref);
            }
        }
        assert!((e - e_ref).abs() < 1e-9 * (1.0 + e_ref.abs()),
                "{e} vs {e_ref}");
        for i in 0..n {
            for k in 0..3 {
                assert!((f[i][k] - f_ref[i][k]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn periodic_forces_sum_to_zero_and_match_brute() {
        let mut rng = Rng::new(3);
        let cell = Cell::orthorhombic(7.0, 8.0, 9.0);
        let pot = Potential::lj(1.0, 1.0, 2.8);
        let n = 30;
        let pos: Vec<[f64; 3]> = (0..n)
            .map(|_| [rng.uniform(0.0, 7.0), rng.uniform(0.0, 8.0),
                      rng.uniform(0.0, 9.0)])
            .collect();
        let species = vec![0usize; n];
        let (e, f) = pot.energy_forces_periodic(&pos, &species, &cell);
        assert!(e.is_finite());
        for k in 0..3 {
            let s: f64 = f.iter().map(|v| v[k]).sum();
            assert!(s.abs() < 1e-9, "net force along {k}: {s}");
        }
        // brute minimum-image reference
        let mut e_ref = 0.0;
        let mut f_ref = vec![[0.0f64; 3]; n];
        for i in 0..n {
            for j in (i + 1)..n {
                let (d, _) = cell.min_image(sub(pos[i], pos[j]));
                let kind = pot.pair_kind(species[i], species[j]);
                accumulate_pair(&kind, d, i, j, &mut e_ref, &mut f_ref);
            }
        }
        assert!((e - e_ref).abs() < 1e-9 * (1.0 + e_ref.abs()));
        for i in 0..n {
            for k in 0..3 {
                assert!((f[i][k] - f_ref[i][k]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn periodic_matches_open_for_isolated_cluster() {
        // a cluster far smaller than the box never sees its images, so
        // periodic and open evaluations coincide
        let mut rng = Rng::new(11);
        let pot = Potential::lj(1.0, 1.0, 2.5);
        let n = 12;
        let pos: Vec<[f64; 3]> = (0..n)
            .map(|_| [20.0 + rng.uniform(0.0, 3.0),
                      20.0 + rng.uniform(0.0, 3.0),
                      20.0 + rng.uniform(0.0, 3.0)])
            .collect();
        let species = vec![0usize; n];
        let cell = Cell::cubic(50.0);
        let (e_open, f_open) = pot.energy_forces(&pos, &species);
        let (e_per, f_per) = pot.energy_forces_periodic(&pos, &species, &cell);
        assert!((e_open - e_per).abs() < 1e-10 * (1.0 + e_open.abs()));
        for i in 0..n {
            for k in 0..3 {
                assert!((f_open[i][k] - f_per[i][k]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn with_list_matches_direct_periodic() {
        let mut rng = Rng::new(5);
        let cell = Cell::cubic(9.0);
        let pot = Potential::lj(1.0, 1.0, 2.5);
        let n = 25;
        let mut pos: Vec<[f64; 3]> = (0..n)
            .map(|_| [rng.uniform(0.0, 9.0), rng.uniform(0.0, 9.0),
                      rng.uniform(0.0, 9.0)])
            .collect();
        let species = vec![0usize; n];
        let mut list = VerletList::periodic(cell, 2.5, 0.6);
        let mut forces = Vec::new();
        for step in 0..5 {
            let e = pot.energy_forces_with_list(
                &pos, &species, &mut list, &mut forces);
            let (e_ref, f_ref) =
                pot.energy_forces_periodic(&pos, &species, &cell);
            assert!((e - e_ref).abs() < 1e-9 * (1.0 + e_ref.abs()),
                    "step {step}: {e} vs {e_ref}");
            for i in 0..n {
                for k in 0..3 {
                    assert!((forces[i][k] - f_ref[i][k]).abs() < 1e-9);
                }
            }
            // drift atoms a little (stays under skin/2 most steps, so
            // both reuse AND rebuild paths are exercised across steps)
            for p in pos.iter_mut() {
                for v in p.iter_mut() {
                    *v += rng.uniform(-0.12, 0.12);
                }
            }
        }
        assert!(list.rebuilds >= 1);
    }

    #[test]
    fn periodic_potential_provider_runs_md() {
        use crate::md::integrator::{Integrator, Thermostat};
        let cell = Cell::cubic(6.0);
        // 2x2x2 simple cubic lattice at spacing 3.0
        let mut pos = Vec::new();
        for x in 0..2 {
            for y in 0..2 {
                for z in 0..2 {
                    pos.push([1.5 + 3.0 * x as f64, 1.5 + 3.0 * y as f64,
                              1.5 + 3.0 * z as f64]);
                }
            }
        }
        let species = vec![0usize; pos.len()];
        let mut pp = PeriodicPotential::new(
            Potential::lj(1.0, 1.0, 2.5), species.clone(), cell, 0.4);
        let (e0, f0) = pp.energy_forces(&pos);
        assert!(e0.is_finite());
        assert_eq!(f0.len(), pos.len());
        let mut rng = Rng::new(42);
        let mut md = Integrator::new_with(pos, species, &mut pp, 0.002,
                                          Thermostat::None);
        md.thermalize(0.1, &mut rng);
        for _ in 0..50 {
            md.step_with(&mut pp, &mut rng);
        }
        assert!(md.pos.iter().all(|p| p.iter().all(|v| v.is_finite())));
        assert!(pp.list().rebuilds >= 1);
    }

    #[test]
    fn excluded_pairs_sorted_and_deduped() {
        let mut pot = Potential::lj(1.0, 1.0, 5.0);
        pot.exclude_bonded_nonbonded = true;
        pot.bonds.push((3, 1, PotentialKind::Harmonic { k: 1.0, r0: 1.0 }));
        pot.bonds.push((0, 2, PotentialKind::Harmonic { k: 1.0, r0: 1.0 }));
        pot.bonds.push((1, 3, PotentialKind::Harmonic { k: 1.0, r0: 1.0 }));
        let ex = pot.excluded_pairs();
        assert_eq!(ex, vec![(0, 2), (1, 3)]);
        // exclusions off -> empty (and Vec::new() never allocates)
        pot.exclude_bonded_nonbonded = false;
        assert!(pot.excluded_pairs().is_empty());
    }

    #[test]
    fn morse_minimum_at_r0() {
        let p = PotentialKind::Morse { d: 2.0, a: 1.5, r0: 1.2 };
        let (e, de) = p.energy_deriv(1.2);
        assert!((e + 2.0).abs() < 1e-12);
        assert!(de.abs() < 1e-12);
    }

    #[test]
    fn harmonic_quadratic() {
        let p = PotentialKind::Harmonic { k: 3.0, r0: 1.0 };
        let (e, de) = p.energy_deriv(1.5);
        assert!((e - 0.375).abs() < 1e-12);
        assert!((de - 1.5).abs() < 1e-12);
    }

    #[test]
    fn forces_are_negative_gradient() {
        // finite-difference check on a random cluster
        let mut rng = Rng::new(0);
        let pot = Potential::lj(1.0, 1.0, 5.0);
        let n = 6;
        let pos: Vec<[f64; 3]> = (0..n)
            .map(|_| [rng.uniform(0.0, 3.0), rng.uniform(0.0, 3.0),
                      rng.uniform(0.0, 3.0)])
            .collect();
        let species = vec![0usize; n];
        let (_, f) = pot.energy_forces(&pos, &species);
        let h = 1e-6;
        for i in 0..n {
            for k in 0..3 {
                let mut pp = pos.clone();
                pp[i][k] += h;
                let (ep, _) = pot.energy_forces(&pp, &species);
                pp[i][k] -= 2.0 * h;
                let (em, _) = pot.energy_forces(&pp, &species);
                let fd = -(ep - em) / (2.0 * h);
                assert!(
                    (f[i][k] - fd).abs() < 1e-4 * (1.0 + fd.abs()),
                    "atom {i} axis {k}: {} vs {}",
                    f[i][k],
                    fd
                );
            }
        }
    }

    #[test]
    fn forces_sum_to_zero() {
        let mut rng = Rng::new(1);
        let pot = Potential::lj(0.5, 1.1, 4.0);
        let pos: Vec<[f64; 3]> = (0..8)
            .map(|_| [rng.normal(), rng.normal(), rng.normal()])
            .collect();
        let (_, f) = pot.energy_forces(&pos, &vec![0; 8]);
        for k in 0..3 {
            let s: f64 = f.iter().map(|v| v[k]).sum();
            assert!(s.abs() < 1e-10);
        }
    }

    #[test]
    fn bonded_terms_apply() {
        let mut pot = Potential::lj(1.0, 1.0, 5.0);
        pot.bonds.push((0, 1, PotentialKind::Harmonic { k: 10.0, r0: 1.0 }));
        pot.exclude_bonded_nonbonded = true;
        let pos = vec![[0.0, 0.0, 0.0], [1.5, 0.0, 0.0]];
        let (e, f) = pot.energy_forces(&pos, &[0, 0]);
        assert!((e - 0.5 * 10.0 * 0.25).abs() < 1e-12);
        assert!((f[0][0] - 5.0).abs() < 1e-12); // pulled toward the bond
    }
}
