//! Potentials with analytic forces.
//!
//! * Classical terms (Lennard-Jones, Morse, harmonic bonds) — the
//!   ground-truth label generators for the synthetic OC20/3BPA-analog
//!   datasets.
//! * [`LearnedPotential`] — the trained Gaunt-engine [`Model`] wrapped
//!   as a force provider, so `md::relax` (FIRE) and
//!   `md::integrator` drive the REAL learned force field exactly like
//!   the classical one.
//! * [`SystemPotential`] — the closed enum over both, letting drivers
//!   switch ground truth <-> learned model with one constructor.

use std::sync::Arc;

use crate::model::{Model, ModelScratch};
use super::relax::ForceProvider;

/// Pairwise potential kinds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PotentialKind {
    /// 4 eps ((s/r)^12 - (s/r)^6), smoothly cut at r_cut.
    LennardJones { eps: f64, sigma: f64, r_cut: f64 },
    /// D (1 - e^{-a(r - r0)})^2 - D.
    Morse { d: f64, a: f64, r0: f64 },
    /// (k/2)(r - r0)^2 (used for bonded terms).
    Harmonic { k: f64, r0: f64 },
}

impl PotentialKind {
    /// (energy, dE/dr) at scalar distance r.
    pub fn energy_deriv(&self, r: f64) -> (f64, f64) {
        match *self {
            PotentialKind::LennardJones { eps, sigma, r_cut } => {
                if r >= r_cut {
                    return (0.0, 0.0);
                }
                let sr6 = (sigma / r).powi(6);
                let sr12 = sr6 * sr6;
                // shift so e(r_cut) = 0 (keeps energies continuous)
                let src6 = (sigma / r_cut).powi(6);
                let shift = 4.0 * eps * (src6 * src6 - src6);
                let e = 4.0 * eps * (sr12 - sr6) - shift;
                let de = 4.0 * eps * (-12.0 * sr12 + 6.0 * sr6) / r;
                (e, de)
            }
            PotentialKind::Morse { d, a, r0 } => {
                let x = (-a * (r - r0)).exp();
                let e = d * (1.0 - x) * (1.0 - x) - d;
                let de = 2.0 * d * a * (1.0 - x) * x;
                (e, de)
            }
            PotentialKind::Harmonic { k, r0 } => {
                let e = 0.5 * k * (r - r0) * (r - r0);
                let de = k * (r - r0);
                (e, de)
            }
        }
    }
}

/// A full system potential: per-species-pair nonbonded terms + explicit
/// bonded terms.
#[derive(Clone, Debug)]
pub struct Potential {
    pub n_species: usize,
    /// nonbonded[s1 * n_species + s2]
    pub nonbonded: Vec<PotentialKind>,
    /// (i, j, kind) explicit bonds (applied in addition to nonbonded)
    pub bonds: Vec<(usize, usize, PotentialKind)>,
    /// bonded pairs excluded from nonbonded interactions
    pub exclude_bonded_nonbonded: bool,
}

impl Potential {
    /// Homogeneous LJ for quick tests.
    pub fn lj(eps: f64, sigma: f64, r_cut: f64) -> Self {
        Potential {
            n_species: 1,
            nonbonded: vec![PotentialKind::LennardJones { eps, sigma, r_cut }],
            bonds: Vec::new(),
            exclude_bonded_nonbonded: false,
        }
    }

    fn is_bonded(&self, i: usize, j: usize) -> bool {
        self.bonds
            .iter()
            .any(|(a, b, _)| (*a == i && *b == j) || (*a == j && *b == i))
    }

    /// Total energy + forces.  `species[i]` indexes the nonbonded table.
    pub fn energy_forces(&self, pos: &[[f64; 3]], species: &[usize])
        -> (f64, Vec<[f64; 3]>) {
        let n = pos.len();
        let mut e = 0.0;
        let mut f = vec![[0.0f64; 3]; n];
        let add_pair = |i: usize, j: usize, kind: &PotentialKind,
                            e: &mut f64, f: &mut Vec<[f64; 3]>| {
            let d = [
                pos[i][0] - pos[j][0],
                pos[i][1] - pos[j][1],
                pos[i][2] - pos[j][2],
            ];
            let r = (d[0] * d[0] + d[1] * d[1] + d[2] * d[2]).sqrt().max(1e-9);
            let (pe, de) = kind.energy_deriv(r);
            *e += pe;
            // F_i = -dE/dr * d/r ; F_j = -F_i
            let s = -de / r;
            for k in 0..3 {
                f[i][k] += s * d[k];
                f[j][k] -= s * d[k];
            }
        };
        for i in 0..n {
            for j in (i + 1)..n {
                if self.exclude_bonded_nonbonded && self.is_bonded(i, j) {
                    continue;
                }
                let kind = self.nonbonded
                    [species[i] * self.n_species + species[j]];
                add_pair(i, j, &kind, &mut e, &mut f);
            }
        }
        for (i, j, kind) in &self.bonds {
            add_pair(*i, *j, kind, &mut e, &mut f);
        }
        (e, f)
    }
}

/// The trained model as an MD/relaxation force provider: owns its
/// species assignment, scratch, and reusable force buffer, so repeated
/// evaluations along a trajectory reuse one workspace.
pub struct LearnedPotential {
    pub model: Arc<Model>,
    pub species: Vec<usize>,
    scratch: ModelScratch,
    forces_flat: Vec<f64>,
}

impl LearnedPotential {
    pub fn new(model: Arc<Model>, species: Vec<usize>) -> LearnedPotential {
        assert!(species.len() <= model.cfg.max_atoms);
        let scratch = model.scratch();
        let forces_flat = vec![0.0; 3 * species.len()];
        LearnedPotential { model, species, scratch, forces_flat }
    }

    /// Energy + forces at `pos` (neighbor list rebuilt per call; the
    /// model evaluation itself reuses the held scratch).
    pub fn compute(&mut self, pos: &[[f64; 3]]) -> (f64, Vec<[f64; 3]>) {
        assert_eq!(pos.len(), self.species.len());
        let edges = self.model.build_edges(pos);
        let e = self.model.energy_forces_into(
            pos, &self.species, &edges, &mut self.forces_flat,
            &mut self.scratch,
        );
        let forces = self
            .forces_flat
            .chunks_exact(3)
            .map(|c| [c[0], c[1], c[2]])
            .collect();
        (e, forces)
    }
}

impl ForceProvider for LearnedPotential {
    fn energy_forces(&mut self, pos: &[[f64; 3]]) -> (f64, Vec<[f64; 3]>) {
        self.compute(pos)
    }
}

/// Either force field behind one façade: ground-truth classical terms or
/// the served/learned Gaunt model.  Implements [`ForceProvider`], so
/// FIRE relaxation and the MD integrator run identically on both.
pub enum SystemPotential {
    Classical { potential: Potential, species: Vec<usize> },
    Learned(LearnedPotential),
}

impl SystemPotential {
    pub fn classical(potential: Potential, species: Vec<usize>)
        -> SystemPotential {
        SystemPotential::Classical { potential, species }
    }

    pub fn learned(model: Arc<Model>, species: Vec<usize>)
        -> SystemPotential {
        SystemPotential::Learned(LearnedPotential::new(model, species))
    }

    pub fn compute(&mut self, pos: &[[f64; 3]]) -> (f64, Vec<[f64; 3]>) {
        match self {
            SystemPotential::Classical { potential, species } => {
                potential.energy_forces(pos, species)
            }
            SystemPotential::Learned(lp) => lp.compute(pos),
        }
    }
}

impl ForceProvider for SystemPotential {
    fn energy_forces(&mut self, pos: &[[f64; 3]]) -> (f64, Vec<[f64; 3]>) {
        self.compute(pos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn learned_potential_drives_fire_and_matches_model() {
        use crate::md::relax::{fire_relax, FireConfig};
        use crate::model::ModelConfig;
        let model = Arc::new(Model::new(
            ModelConfig { n_layers: 1, ..Default::default() }, 5));
        let species = vec![0usize, 1, 2, 0];
        let mut rng = Rng::new(2);
        let pos: Vec<[f64; 3]> = (0..4)
            .map(|_| [rng.normal(), rng.normal(), rng.normal()])
            .collect();
        let mut lp = LearnedPotential::new(model.clone(), species.clone());
        let (e, f) = lp.compute(&pos);
        let (e2, f2) = model.energy_forces(&pos, &species);
        assert_eq!(e, e2);
        assert_eq!(f, f2);
        // a few FIRE steps through the provider must run and stay finite
        let mut sys = SystemPotential::learned(model, species);
        let res = fire_relax(&mut sys, &pos,
                             FireConfig { max_steps: 5, ..Default::default() });
        assert!(res.energy.is_finite());
        assert_eq!(res.energy_trace.len(), res.steps + 1);
    }

    #[test]
    fn system_potential_classical_matches_direct() {
        let pot = Potential::lj(1.0, 1.0, 5.0);
        let species = vec![0usize; 3];
        let pos = vec![[0.0; 3], [1.2, 0.0, 0.0], [0.0, 1.3, 0.0]];
        let (e, f) = pot.energy_forces(&pos, &species);
        let mut sys = SystemPotential::classical(pot, species);
        let (e2, f2) = sys.compute(&pos);
        assert_eq!(e, e2);
        assert_eq!(f, f2);
    }

    #[test]
    fn lj_minimum_at_r_min() {
        let p = PotentialKind::LennardJones { eps: 1.0, sigma: 1.0, r_cut: 10.0 };
        let r_min = 2f64.powf(1.0 / 6.0);
        let (_, d) = p.energy_deriv(r_min);
        assert!(d.abs() < 1e-10);
        let (e, _) = p.energy_deriv(r_min);
        assert!((e + 1.0).abs() < 1e-3); // ~ -eps (small cutoff shift)
    }

    #[test]
    fn lj_cutoff_continuous() {
        let p = PotentialKind::LennardJones { eps: 1.0, sigma: 1.0, r_cut: 2.5 };
        let (e_in, _) = p.energy_deriv(2.4999);
        let (e_out, _) = p.energy_deriv(2.5001);
        assert!(e_in.abs() < 1e-2 && e_out == 0.0);
    }

    #[test]
    fn morse_minimum_at_r0() {
        let p = PotentialKind::Morse { d: 2.0, a: 1.5, r0: 1.2 };
        let (e, de) = p.energy_deriv(1.2);
        assert!((e + 2.0).abs() < 1e-12);
        assert!(de.abs() < 1e-12);
    }

    #[test]
    fn harmonic_quadratic() {
        let p = PotentialKind::Harmonic { k: 3.0, r0: 1.0 };
        let (e, de) = p.energy_deriv(1.5);
        assert!((e - 0.375).abs() < 1e-12);
        assert!((de - 1.5).abs() < 1e-12);
    }

    #[test]
    fn forces_are_negative_gradient() {
        // finite-difference check on a random cluster
        let mut rng = Rng::new(0);
        let pot = Potential::lj(1.0, 1.0, 5.0);
        let n = 6;
        let pos: Vec<[f64; 3]> = (0..n)
            .map(|_| [rng.uniform(0.0, 3.0), rng.uniform(0.0, 3.0),
                      rng.uniform(0.0, 3.0)])
            .collect();
        let species = vec![0usize; n];
        let (_, f) = pot.energy_forces(&pos, &species);
        let h = 1e-6;
        for i in 0..n {
            for k in 0..3 {
                let mut pp = pos.clone();
                pp[i][k] += h;
                let (ep, _) = pot.energy_forces(&pp, &species);
                pp[i][k] -= 2.0 * h;
                let (em, _) = pot.energy_forces(&pp, &species);
                let fd = -(ep - em) / (2.0 * h);
                assert!(
                    (f[i][k] - fd).abs() < 1e-4 * (1.0 + fd.abs()),
                    "atom {i} axis {k}: {} vs {}",
                    f[i][k],
                    fd
                );
            }
        }
    }

    #[test]
    fn forces_sum_to_zero() {
        let mut rng = Rng::new(1);
        let pot = Potential::lj(0.5, 1.1, 4.0);
        let pos: Vec<[f64; 3]> = (0..8)
            .map(|_| [rng.normal(), rng.normal(), rng.normal()])
            .collect();
        let (_, f) = pot.energy_forces(&pos, &vec![0; 8]);
        for k in 0..3 {
            let s: f64 = f.iter().map(|v| v[k]).sum();
            assert!(s.abs() < 1e-10);
        }
    }

    #[test]
    fn bonded_terms_apply() {
        let mut pot = Potential::lj(1.0, 1.0, 5.0);
        pot.bonds.push((0, 1, PotentialKind::Harmonic { k: 10.0, r0: 1.0 }));
        pot.exclude_bonded_nonbonded = true;
        let pos = vec![[0.0, 0.0, 0.0], [1.5, 0.0, 0.0]];
        let (e, f) = pot.energy_forces(&pos, &[0, 0]);
        assert!((e - 0.5 * 10.0 * 0.25).abs() < 1e-12);
        assert!((f[0][0] - 5.0).abs() < 1e-12); // pulled toward the bond
    }
}
