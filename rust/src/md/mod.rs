//! Molecular-dynamics substrate, from scratch.
//!
//! The paper's evaluations need datasets we cannot download offline (OC20
//! DFT relaxations, 3BPA MD test sets at 300/600/1200 K).  This module is
//! the substitute data engine (DESIGN.md §3): classical potentials with
//! exact forces, a velocity-Verlet / Langevin integrator, neighbor search
//! (open-boundary AND periodic minimum-image cell lists with Verlet-skin
//! reuse, DESIGN.md §13), and a flexible-molecule builder, used to sample
//! configuration datasets with in- and out-of-distribution temperature
//! splits exactly like the 3BPA protocol — plus OCP-style periodic slabs.

pub mod integrator;
pub mod molecule;
pub mod neighbor;
pub mod potential;
pub mod relax;

pub use integrator::{Integrator, Thermostat};
pub use molecule::Molecule;
pub use neighbor::{neighbors_brute, neighbors_cell,
                   neighbors_periodic_brute, neighbors_periodic_cell,
                   neighbors_periodic_par, Cell, CellListScratch, Edge,
                   VerletList};
pub use potential::{LearnedPotential, PeriodicPotential, Potential,
                    PotentialKind, SystemPotential};
pub use relax::{fire_relax, FireConfig, ForceProvider, RelaxResult};
