//! Offline stub of the `xla` PJRT bindings (DESIGN.md section 5).
//!
//! The runtime layer ([`crate::runtime`]) is written against the small
//! surface of the `xla` crate (PJRT CPU client, HLO-text compilation,
//! literal transfer).  That crate links a native XLA build, which cannot
//! exist in the offline environment, so this module provides the same API
//! as a seam: types construct and shape-check normally, and the first
//! operation that would need the native runtime (`compile`/`execute`/
//! `to_vec`) returns a descriptive [`Error`].
//!
//! Because no `artifacts/` manifest ships in an offline checkout, every
//! artifact-dependent test and bench already skips before reaching these
//! calls — the stub exists so the crate builds, the seam stays typed, and
//! a real PJRT backend can be swapped in behind the same signatures.

use std::fmt;

/// Error type mirroring the `xla` crate's.
#[derive(Clone, Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

impl From<Error> for crate::util::error::Error {
    fn from(e: Error) -> Self {
        crate::util::error::Error::msg(e)
    }
}

/// Result alias matching the `xla` crate's.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: the native XLA/PJRT runtime is not linked in this offline \
         build; the typed seam in src/xla.rs stands in for it (DESIGN.md \
         section 5)"
    ))
}

/// Element types a [`Literal`] can carry.
pub trait NativeType: Copy {
    /// Human-readable dtype name (diagnostics only).
    const DTYPE: &'static str;
}

impl NativeType for f32 {
    const DTYPE: &'static str = "f32";
}

impl NativeType for f64 {
    const DTYPE: &'static str = "f64";
}

impl NativeType for i32 {
    const DTYPE: &'static str = "i32";
}

impl NativeType for i64 {
    const DTYPE: &'static str = "i64";
}

/// Host-side tensor handle.  The stub tracks element count and dims so
/// `reshape` shape-checks exactly like the real bindings.
#[derive(Clone, Debug, Default)]
pub struct Literal {
    elems: usize,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal over a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { elems: data.len(), dims: vec![data.len() as i64] }
    }

    /// Current dimensions.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Reshape; errors when the element count does not match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n < 0 || n as usize != self.elems {
            return Err(Error(format!(
                "reshape: cannot view {} elements as {:?}",
                self.elems, dims
            )));
        }
        Ok(Literal { elems: self.elems, dims: dims.to_vec() })
    }

    /// Copy out as a host vector (needs the native runtime).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    /// Destructure a tuple literal (needs the native runtime).
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    /// Destructure a 1-tuple literal (needs the native runtime).
    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(unavailable("Literal::to_tuple1"))
    }
}

/// Parsed HLO module text.
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    /// Load HLO text from a file (real file IO; only compilation is
    /// stubbed).
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        match std::fs::read_to_string(path) {
            Ok(text) => Ok(HloModuleProto { text }),
            Err(e) => Err(Error(format!("{path}: {e}"))),
        }
    }

    /// The raw HLO text.
    pub fn text(&self) -> &str {
        &self.text
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    text: String,
}

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { text: proto.text.clone() }
    }

    /// The raw HLO text.
    pub fn text(&self) -> &str {
        &self.text
    }
}

/// A compiled executable handle (never constructed by the stub: `compile`
/// is where the offline build reports unavailability).
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given argument literals.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer handle.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    /// Transfer device buffer to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// PJRT client handle.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    /// The CPU client.  Construction succeeds (so manifest-level errors
    /// surface first, exactly as with the real bindings); `compile` is
    /// the unavailable operation.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _priv: () })
    }

    /// Platform name (diagnostics).
    pub fn platform_name(&self) -> String {
        "cpu-offline-stub".to_string()
    }

    /// Compile a computation (needs the native runtime).
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_shape_checks() {
        let l = Literal::vec1(&[1.0f32; 12]);
        assert_eq!(l.dims(), &[12]);
        let r = l.reshape(&[3, 4]).unwrap();
        assert_eq!(r.dims(), &[3, 4]);
        assert!(l.reshape(&[5, 5]).is_err());
        assert!(Literal::vec1(&[1i32, 2]).reshape(&[2]).is_ok());
    }

    #[test]
    fn unavailable_operations_report_the_seam() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "cpu-offline-stub");
        let e = Literal::vec1(&[0.0f64]).to_vec::<f64>().unwrap_err();
        assert!(e.to_string().contains("offline"), "{e}");
    }

    #[test]
    fn hlo_text_round_trips_through_proto() {
        let dir = std::env::temp_dir().join("gaunt_tp_xla_stub_test.hlo.txt");
        std::fs::write(&dir, "HloModule stub_test").unwrap();
        let proto = HloModuleProto::from_text_file(dir.to_str().unwrap()).unwrap();
        let comp = XlaComputation::from_proto(&proto);
        assert_eq!(comp.text(), "HloModule stub_test");
        assert!(PjRtClient::cpu().unwrap().compile(&comp).is_err());
        let _ = std::fs::remove_file(&dir);
        assert!(HloModuleProto::from_text_file("/no/such/file.hlo").is_err());
    }
}
