//! Charged N-body simulator — the Fig. 1 sanity-check substrate.
//!
//! Reimplements the 5-particle charged system of Satorras et al. (2021):
//! particles carry charge ±1, interact via a softened Coulomb force, and
//! the learning task is to forecast positions after `horizon` steps from
//! (position, velocity, charge) at t = 0.

use crate::util::rng::Rng;

/// One trajectory sample: inputs at t=0 and the target positions.
#[derive(Clone, Debug)]
pub struct NbodySample {
    pub pos: Vec<[f64; 3]>,
    pub vel: Vec<[f64; 3]>,
    /// 0 => charge -1, 1 => charge +1 (species index for the model)
    pub charge: Vec<usize>,
    pub target: Vec<[f64; 3]>,
}

/// Simulation parameters (defaults follow the EGNN/SEGNN setup scaled to
/// a shorter horizon for CPU budgets).
#[derive(Clone, Copy, Debug)]
pub struct NbodyConfig {
    pub n_particles: usize,
    pub dt: f64,
    pub horizon_steps: usize,
    pub softening: f64,
}

impl Default for NbodyConfig {
    fn default() -> Self {
        NbodyConfig { n_particles: 5, dt: 0.001, horizon_steps: 1000,
                      softening: 0.1 }
    }
}

/// Softened Coulomb forces: F_i = sum_j q_i q_j (r_i - r_j) / (|r|^2+eps)^{3/2}.
pub fn coulomb_forces(pos: &[[f64; 3]], q: &[f64], softening: f64)
    -> Vec<[f64; 3]> {
    let n = pos.len();
    let mut f = vec![[0.0f64; 3]; n];
    for i in 0..n {
        for j in 0..n {
            if i == j {
                continue;
            }
            let d = [
                pos[i][0] - pos[j][0],
                pos[i][1] - pos[j][1],
                pos[i][2] - pos[j][2],
            ];
            let r2 = d[0] * d[0] + d[1] * d[1] + d[2] * d[2]
                + softening * softening;
            let inv = q[i] * q[j] / (r2 * r2.sqrt());
            for k in 0..3 {
                f[i][k] += inv * d[k];
            }
        }
    }
    f
}

/// Generate one trajectory sample with leapfrog integration.
pub fn simulate(cfg: &NbodyConfig, rng: &mut Rng) -> NbodySample {
    let n = cfg.n_particles;
    let pos0: Vec<[f64; 3]> = (0..n)
        .map(|_| [rng.normal() * 0.5, rng.normal() * 0.5, rng.normal() * 0.5])
        .collect();
    let vel0: Vec<[f64; 3]> = (0..n)
        .map(|_| [rng.normal() * 0.5, rng.normal() * 0.5, rng.normal() * 0.5])
        .collect();
    let charge: Vec<usize> = (0..n).map(|_| rng.below(2)).collect();
    let q: Vec<f64> = charge.iter().map(|&c| if c == 1 { 1.0 } else { -1.0 })
        .collect();
    let mut pos = pos0.clone();
    let mut vel = vel0.clone();
    let mut f = coulomb_forces(&pos, &q, cfg.softening);
    for _ in 0..cfg.horizon_steps {
        for i in 0..n {
            for k in 0..3 {
                vel[i][k] += 0.5 * cfg.dt * f[i][k];
                pos[i][k] += cfg.dt * vel[i][k];
            }
        }
        f = coulomb_forces(&pos, &q, cfg.softening);
        for i in 0..n {
            for k in 0..3 {
                vel[i][k] += 0.5 * cfg.dt * f[i][k];
            }
        }
    }
    NbodySample { pos: pos0, vel: vel0, charge, target: pos }
}

/// A dataset of independent trajectories.
pub fn dataset(cfg: &NbodyConfig, n_samples: usize, seed: u64)
    -> Vec<NbodySample> {
    let mut rng = Rng::new(seed);
    (0..n_samples).map(|_| simulate(cfg, &mut rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forces_antisymmetric() {
        let mut rng = Rng::new(0);
        let pos: Vec<[f64; 3]> = (0..4)
            .map(|_| [rng.normal(), rng.normal(), rng.normal()])
            .collect();
        let q = vec![1.0, -1.0, 1.0, -1.0];
        let f = coulomb_forces(&pos, &q, 0.1);
        for k in 0..3 {
            let s: f64 = f.iter().map(|v| v[k]).sum();
            assert!(s.abs() < 1e-12, "momentum not conserved");
        }
    }

    #[test]
    fn like_charges_repel() {
        let pos = vec![[0.0, 0.0, 0.0], [1.0, 0.0, 0.0]];
        let f = coulomb_forces(&pos, &[1.0, 1.0], 0.01);
        assert!(f[0][0] < 0.0 && f[1][0] > 0.0);
        let f2 = coulomb_forces(&pos, &[1.0, -1.0], 0.01);
        assert!(f2[0][0] > 0.0 && f2[1][0] < 0.0);
    }

    #[test]
    fn simulation_moves_particles() {
        let mut rng = Rng::new(1);
        let cfg = NbodyConfig::default();
        let s = simulate(&cfg, &mut rng);
        let moved: f64 = s
            .pos
            .iter()
            .zip(&s.target)
            .map(|(a, b)| {
                ((a[0] - b[0]).powi(2) + (a[1] - b[1]).powi(2)
                    + (a[2] - b[2]).powi(2))
                .sqrt()
            })
            .sum();
        assert!(moved > 0.1, "particles barely moved");
        assert!(s.target.iter().all(|p| p.iter().all(|x| x.is_finite())));
    }

    #[test]
    fn dataset_deterministic_by_seed() {
        let cfg = NbodyConfig { horizon_steps: 50, ..Default::default() };
        let a = dataset(&cfg, 3, 42);
        let b = dataset(&cfg, 3, 42);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.charge, y.charge);
            for (p, q) in x.target.iter().zip(&y.target) {
                assert_eq!(p, q);
            }
        }
    }

    #[test]
    fn trajectory_is_smooth_short_horizon() {
        // shorter horizon => smaller displacement (continuity in horizon)
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let short = simulate(
            &NbodyConfig { horizon_steps: 10, ..Default::default() }, &mut r1);
        let long = simulate(
            &NbodyConfig { horizon_steps: 400, ..Default::default() }, &mut r2);
        let disp = |s: &NbodySample| -> f64 {
            s.pos.iter().zip(&s.target).map(|(a, b)| {
                ((a[0]-b[0]).powi(2)+(a[1]-b[1]).powi(2)+(a[2]-b[2]).powi(2))
                    .sqrt()
            }).sum()
        };
        assert!(disp(&short) < disp(&long));
    }
}
