//! Evaluation metrics: the OC20 S2EF metric set (Table 1) + MAEs (Table 2).

/// Mean absolute error of two equal-length slices.
pub fn mae(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    pred.iter().zip(truth).map(|(a, b)| (a - b).abs()).sum::<f64>()
        / pred.len() as f64
}

/// Per-component force MAE over a set of (pred, truth) force arrays.
pub fn force_mae(pred: &[Vec<[f64; 3]>], truth: &[Vec<[f64; 3]>]) -> f64 {
    let mut acc = 0.0;
    let mut count = 0usize;
    for (p, t) in pred.iter().zip(truth) {
        for (a, b) in p.iter().zip(t) {
            for k in 0..3 {
                acc += (a[k] - b[k]).abs();
                count += 1;
            }
        }
    }
    if count == 0 { 0.0 } else { acc / count as f64 }
}

/// Mean cosine similarity between predicted and true per-atom forces.
pub fn force_cos(pred: &[Vec<[f64; 3]>], truth: &[Vec<[f64; 3]>]) -> f64 {
    let mut acc = 0.0;
    let mut count = 0usize;
    for (p, t) in pred.iter().zip(truth) {
        for (a, b) in p.iter().zip(t) {
            let na = (a[0] * a[0] + a[1] * a[1] + a[2] * a[2]).sqrt();
            let nb = (b[0] * b[0] + b[1] * b[1] + b[2] * b[2]).sqrt();
            if na < 1e-12 || nb < 1e-12 {
                continue;
            }
            acc += (a[0] * b[0] + a[1] * b[1] + a[2] * b[2]) / (na * nb);
            count += 1;
        }
    }
    if count == 0 { 0.0 } else { acc / count as f64 }
}

/// Energy & Forces within Threshold: fraction of structures with
/// |dE| < e_thresh AND max per-atom force error < f_thresh (OC20's EFwT).
pub fn efwt(
    e_pred: &[f64], e_truth: &[f64],
    f_pred: &[Vec<[f64; 3]>], f_truth: &[Vec<[f64; 3]>],
    e_thresh: f64, f_thresh: f64,
) -> f64 {
    let mut ok = 0usize;
    for i in 0..e_pred.len() {
        if (e_pred[i] - e_truth[i]).abs() >= e_thresh {
            continue;
        }
        let mut worst = 0.0f64;
        for (a, b) in f_pred[i].iter().zip(&f_truth[i]) {
            let d = ((a[0] - b[0]).powi(2) + (a[1] - b[1]).powi(2)
                + (a[2] - b[2]).powi(2))
            .sqrt();
            worst = worst.max(d);
        }
        if worst < f_thresh {
            ok += 1;
        }
    }
    ok as f64 / e_pred.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mae_basic() {
        assert_eq!(mae(&[1.0, 2.0], &[1.0, 4.0]), 1.0);
        assert_eq!(mae(&[], &[]), 0.0);
    }

    #[test]
    fn force_cos_perfect_and_opposite() {
        let f = vec![vec![[1.0, 0.0, 0.0], [0.0, 2.0, 0.0]]];
        assert!((force_cos(&f, &f) - 1.0).abs() < 1e-12);
        let neg = vec![vec![[-1.0, 0.0, 0.0], [0.0, -2.0, 0.0]]];
        assert!((force_cos(&f, &neg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn force_mae_counts_components() {
        let a = vec![vec![[1.0, 1.0, 1.0]]];
        let b = vec![vec![[0.0, 0.0, 0.0]]];
        assert!((force_mae(&a, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn efwt_thresholds() {
        let ep = vec![0.0, 0.0];
        let et = vec![0.01, 0.5];
        let fp = vec![vec![[0.0; 3]]; 2];
        let ft = vec![vec![[0.001, 0.0, 0.0]], vec![[0.0; 3]]];
        // first passes (dE 0.01 < 0.02, dF small); second fails on energy
        let v = efwt(&ep, &et, &fp, &ft, 0.02, 0.03);
        assert!((v - 0.5).abs() < 1e-12);
    }
}
