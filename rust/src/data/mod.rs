//! Datasets, padding to the static shapes the compiled executables expect,
//! and evaluation metrics (Energy/Force MAE, Force cos, EFwT — the OC20
//! metric set of Table 1).

pub mod metrics;

use crate::md::integrator::{Integrator, Thermostat};
use crate::md::molecule::Molecule;
use crate::md::neighbor::{neighbors_cell, Cell};
use crate::md::potential::PeriodicPotential;
use crate::util::rng::Rng;

/// One labeled configuration (ground truth from the classical potential —
/// our offline stand-in for DFT labels, see DESIGN.md §3).
#[derive(Clone, Debug)]
pub struct Graph {
    pub pos: Vec<[f64; 3]>,
    pub species: Vec<usize>,
    pub energy: f64,
    pub forces: Vec<[f64; 3]>,
}

impl Graph {
    pub fn n_atoms(&self) -> usize {
        self.pos.len()
    }
}

/// A batch padded to static (B, N, E) shapes, laid out exactly like the
/// `ff_*` artifact inputs (f32/i32 row-major).
#[derive(Clone, Debug)]
pub struct PaddedBatch {
    pub b: usize,
    pub n_atoms: usize,
    pub n_edges: usize,
    pub pos: Vec<f32>,       // [B, N, 3]
    pub species: Vec<i32>,   // [B, N]
    pub edges: Vec<i32>,     // [B, E, 2]
    pub edge_mask: Vec<f32>, // [B, E]
    pub atom_mask: Vec<f32>, // [B, N]
    pub energy: Vec<f32>,    // [B]
    pub forces: Vec<f32>,    // [B, N, 3]
    /// true atom counts per row (for unpadding results)
    pub true_atoms: Vec<usize>,
    /// number of graphs actually occupied (rest are pure padding rows)
    pub occupied: usize,
    /// edges dropped because the graph exceeded the static edge budget
    pub dropped_edges: usize,
}

impl PaddedBatch {
    /// Pad `graphs` (at most `b`) into the static shape; builds edge lists
    /// with a cutoff-radius neighbor search.
    pub fn from_graphs(
        graphs: &[Graph], b: usize, n_atoms: usize, n_edges: usize,
        r_cut: f64,
    ) -> PaddedBatch {
        assert!(graphs.len() <= b, "batch overflow");
        let mut pb = PaddedBatch {
            b,
            n_atoms,
            n_edges,
            pos: vec![0.0; b * n_atoms * 3],
            species: vec![0; b * n_atoms],
            edges: vec![0; b * n_edges * 2],
            edge_mask: vec![0.0; b * n_edges],
            atom_mask: vec![0.0; b * n_atoms],
            energy: vec![0.0; b],
            forces: vec![0.0; b * n_atoms * 3],
            true_atoms: vec![0; b],
            occupied: graphs.len(),
            dropped_edges: 0,
        };
        for (g_idx, g) in graphs.iter().enumerate() {
            let na = g.n_atoms().min(n_atoms);
            pb.true_atoms[g_idx] = na;
            for a in 0..na {
                let base = (g_idx * n_atoms + a) * 3;
                for k in 0..3 {
                    pb.pos[base + k] = g.pos[a][k] as f32;
                    pb.forces[base + k] = g.forces[a][k] as f32;
                }
                pb.species[g_idx * n_atoms + a] = g.species[a] as i32;
                pb.atom_mask[g_idx * n_atoms + a] = 1.0;
            }
            pb.energy[g_idx] = g.energy as f32;
            let nb = neighbors_cell(&g.pos[..na], r_cut);
            let mut e_idx = 0;
            for (i, j) in nb {
                if e_idx >= n_edges {
                    pb.dropped_edges += 1;
                    continue;
                }
                let base = (g_idx * n_edges + e_idx) * 2;
                pb.edges[base] = i as i32;
                pb.edges[base + 1] = j as i32;
                pb.edge_mask[g_idx * n_edges + e_idx] = 1.0;
                e_idx += 1;
            }
        }
        pb
    }
}

/// Sample `n_per_temp` configurations of the 3BPA-lite molecule at each
/// thermostat temperature, labeled by the classical potential — the
/// Table 2 protocol (train at temps[0], test in- and out-of-distribution).
pub fn gen_bpa_dataset(temps: &[f64], n_per_temp: usize, seed: u64)
    -> Vec<Vec<Graph>> {
    let mol = Molecule::bpa_lite();
    let mut out = Vec::with_capacity(temps.len());
    for (ti, &temp) in temps.iter().enumerate() {
        let mut rng = Rng::new(seed.wrapping_add(1000 * ti as u64));
        let mut md = Integrator::new(
            mol.pos.clone(), mol.species.clone(), &mol.potential, 0.002,
            Thermostat::Langevin { gamma: 1.0, temperature: temp },
        );
        md.thermalize(temp, &mut rng);
        // equilibrate
        for _ in 0..1500 {
            md.step(&mol.potential, &mut rng);
        }
        let mut graphs = Vec::with_capacity(n_per_temp);
        while graphs.len() < n_per_temp {
            // decorrelate between samples
            for _ in 0..100 {
                md.step(&mol.potential, &mut rng);
            }
            let (e, f) =
                mol.potential.energy_forces(&md.pos, &md.species);
            graphs.push(Graph {
                pos: md.pos.clone(),
                species: md.species.clone(),
                energy: e,
                forces: f,
            });
        }
        out.push(graphs);
    }
    out
}

/// Dihedral-slice analog: rigidly rotate ring B about the linker axis —
/// samples a PES slice unlike anything in training.  The sweep covers
/// ±60° (full revolutions produce steric clashes with astronomically
/// repulsive LJ energies that would swamp any regression metric; real
/// 3BPA dihedral scans likewise stay in the sterically allowed range).
pub fn gen_dihedral_slices(n: usize) -> Vec<Graph> {
    let mol = Molecule::bpa_lite();
    let pivot = mol.pos[9]; // end of linker chain
    let mut out = Vec::with_capacity(n);
    let max_ang = std::f64::consts::PI / 3.0;
    for k in 0..n {
        let ang = -max_ang + 2.0 * max_ang * k as f64 / (n - 1).max(1) as f64;
        let mut pos = mol.pos.clone();
        for p in pos.iter_mut().skip(10) {
            // rotate about the x-axis through pivot
            let dy = p[1] - pivot[1];
            let dz = p[2] - pivot[2];
            let (s, c) = ang.sin_cos();
            p[1] = pivot[1] + c * dy - s * dz;
            p[2] = pivot[2] + s * dy + c * dz;
        }
        let (e, f) = mol.potential.energy_forces(&pos, &mol.species);
        // guard: skip sterically clashed geometries
        if e < 1e4 {
            out.push(Graph {
                pos,
                species: mol.species.clone(),
                energy: e,
                forces: f,
            });
        }
    }
    out
}

/// OC20-analog dataset: adsorbate-on-slab configurations perturbed and
/// relaxed for a few steps, labels from the classical potential.
pub fn gen_adsorbate_dataset(n: usize, seed: u64) -> Vec<Graph> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(n);
    while out.len() < n {
        let mol = Molecule::adsorbate_slab(3, 3, rng.uniform(-0.3, 0.3));
        let mut md = Integrator::new(
            mol.pos.clone(), mol.species.clone(), &mol.potential, 0.002,
            Thermostat::Langevin { gamma: 2.0, temperature: 0.08 },
        );
        md.thermalize(0.08, &mut rng);
        let steps = 100 + rng.below(400);
        for _ in 0..steps {
            md.step(&mol.potential, &mut rng);
        }
        let (e, f) = mol.potential.energy_forces(&md.pos, &md.species);
        if e.is_finite() {
            out.push(Graph {
                pos: md.pos.clone(),
                species: md.species.clone(),
                energy: e,
                forces: f,
            });
        }
    }
    out
}

/// [`Molecule::lj_box`] at reduced density 0.8 with the standard LJ
/// cutoff 2.5 and a Verlet skin, both clamped so `r_cut + skin` fits
/// the box's minimum-image bound (`0.45 L + 0.05 L = 0.5 L =`
/// [`Cell::max_cutoff`]) — every box size down to a single unit cell
/// stays valid.  Returns `(molecule, cell, skin)`.
fn lj_box_mic(n_side: usize) -> (Molecule, Cell, f64) {
    let n = n_side * n_side * n_side;
    let l = (n as f64 / 0.8).cbrt();
    let skin = 0.4f64.min(0.05 * l);
    let (m, cell) = Molecule::lj_box(n_side, 0.8, 2.5f64.min(0.45 * l));
    (m, cell, skin)
}

/// Periodic LJ bulk dataset: Langevin MD in a cubic box (forces through
/// the Verlet-list periodic path), configurations labeled with the
/// PERIODIC classical energy/forces and positions wrapped into the cell.
/// Returns the graphs plus the shared [`Cell`] — feed both to
/// [`crate::model::Model::build_edges_periodic`] for training/eval.
pub fn gen_periodic_lj_dataset(
    n_side: usize, n_configs: usize, temp: f64, seed: u64,
) -> (Vec<Graph>, Cell) {
    let (m, cell, skin) = lj_box_mic(n_side);
    let mut pp = PeriodicPotential::new(
        m.potential.clone(), m.species.clone(), cell.clone(), skin);
    let mut rng = Rng::new(seed);
    let mut md = Integrator::new_with(
        m.pos.clone(), m.species.clone(), &mut pp, 0.003,
        Thermostat::Langevin { gamma: 1.0, temperature: temp },
    );
    md.thermalize(temp, &mut rng);
    for _ in 0..300 {
        md.step_with(&mut pp, &mut rng);
    }
    let mut out = Vec::with_capacity(n_configs);
    while out.len() < n_configs {
        for _ in 0..50 {
            md.step_with(&mut pp, &mut rng);
        }
        let (e, f) = pp.energy_forces_ref(&md.pos);
        let forces = f.to_vec();
        // labels are wrap-invariant; store canonical in-cell positions
        let pos: Vec<[f64; 3]> = md.pos.iter().map(|p| cell.wrap(*p)).collect();
        out.push(Graph {
            pos,
            species: m.species.clone(),
            energy: e,
            forces,
        });
    }
    (out, cell)
}

/// Normalization statistics (energy is regressed per atom).
#[derive(Clone, Copy, Debug)]
pub struct EnergyStats {
    pub mean_per_atom: f64,
    pub std_per_atom: f64,
}

pub fn energy_stats(graphs: &[Graph]) -> EnergyStats {
    let per_atom: Vec<f64> = graphs
        .iter()
        .map(|g| g.energy / g.n_atoms() as f64)
        .collect();
    let mean = per_atom.iter().sum::<f64>() / per_atom.len() as f64;
    let var = per_atom.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
        / per_atom.len() as f64;
    EnergyStats { mean_per_atom: mean, std_per_atom: var.sqrt().max(1e-9) }
}

/// Shift/scale a dataset's labels in place: e' = (e - n*mean)/std, f' = f/std.
pub fn normalize_graphs(graphs: &mut [Graph], stats: EnergyStats) {
    for g in graphs.iter_mut() {
        g.energy = (g.energy - g.n_atoms() as f64 * stats.mean_per_atom)
            / stats.std_per_atom;
        for f in g.forces.iter_mut() {
            for k in 0..3 {
                f[k] /= stats.std_per_atom;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn padding_shapes() {
        let ds = gen_bpa_dataset(&[0.05], 3, 0);
        let pb = PaddedBatch::from_graphs(&ds[0], 4, 32, 128, 4.0);
        assert_eq!(pb.pos.len(), 4 * 32 * 3);
        assert_eq!(pb.edges.len(), 4 * 128 * 2);
        assert_eq!(pb.occupied, 3);
        assert_eq!(pb.true_atoms[0], 14);
        assert_eq!(pb.true_atoms[3], 0); // padding row
        // masks consistent
        let atoms0: f32 = pb.atom_mask[0..32].iter().sum();
        assert_eq!(atoms0, 14.0);
        let atoms3: f32 = pb.atom_mask[3 * 32..4 * 32].iter().sum();
        assert_eq!(atoms3, 0.0);
    }

    #[test]
    fn padded_edges_in_range() {
        let ds = gen_bpa_dataset(&[0.05], 2, 1);
        let pb = PaddedBatch::from_graphs(&ds[0], 2, 32, 128, 4.0);
        for g in 0..2 {
            for e in 0..128 {
                if pb.edge_mask[g * 128 + e] > 0.0 {
                    let i = pb.edges[(g * 128 + e) * 2];
                    let j = pb.edges[(g * 128 + e) * 2 + 1];
                    assert!(i >= 0 && (i as usize) < 14);
                    assert!(j >= 0 && (j as usize) < 14);
                    assert_ne!(i, j);
                }
            }
        }
    }

    #[test]
    fn bpa_dataset_temperatures_distinct() {
        let ds = gen_bpa_dataset(&[0.02, 0.3], 5, 2);
        // higher-T configurations have higher mean energy
        let mean_e = |gs: &[Graph]| -> f64 {
            gs.iter().map(|g| g.energy).sum::<f64>() / gs.len() as f64
        };
        assert!(mean_e(&ds[1]) > mean_e(&ds[0]));
    }

    #[test]
    fn dihedral_slices_vary() {
        let sl = gen_dihedral_slices(8);
        // clash guard may drop extreme angles, but most slices survive
        assert!(sl.len() >= 4, "only {} slices", sl.len());
        assert!(sl.iter().all(|g| g.energy < 1e4));
        let e: Vec<f64> = sl.iter().map(|g| g.energy).collect();
        let spread = e.iter().cloned().fold(f64::MIN, f64::max)
            - e.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread > 1e-3, "slices should change the energy");
    }

    #[test]
    fn adsorbate_dataset_valid() {
        let ds = gen_adsorbate_dataset(3, 0);
        for g in &ds {
            assert_eq!(g.n_atoms(), 21);
            assert!(g.energy.is_finite());
            assert_eq!(g.forces.len(), 21);
        }
    }

    #[test]
    fn periodic_lj_dataset_labels_are_periodic_and_wrapped() {
        let (ds, cell) = gen_periodic_lj_dataset(3, 2, 0.1, 0);
        assert_eq!(ds.len(), 2);
        let l = cell.lattice()[0][0];
        for g in &ds {
            assert_eq!(g.n_atoms(), 27);
            assert!(g.energy.is_finite());
            // positions wrapped into the home cell
            for p in &g.pos {
                for k in 0..3 {
                    assert!(p[k] >= -1e-9 && p[k] < l + 1e-9);
                }
            }
            // labels match a fresh periodic evaluation of the wrapped
            // positions (wrap-invariance of the minimum-image energy)
            let (m, _, _) = lj_box_mic(3);
            let (e, f) = m.potential.energy_forces_periodic(
                &g.pos, &g.species, &cell);
            assert!((e - g.energy).abs() < 1e-9 * (1.0 + e.abs()));
            for (a, b) in f.iter().zip(&g.forces) {
                for k in 0..3 {
                    assert!((a[k] - b[k]).abs() < 1e-9);
                }
            }
            // net force vanishes under PBC
            for k in 0..3 {
                let s: f64 = g.forces.iter().map(|v| v[k]).sum();
                assert!(s.abs() < 1e-9);
            }
        }
    }

    #[test]
    fn periodic_lj_dataset_handles_small_boxes() {
        // boxes where the standard cutoff 2.5 (and the default 0.4
        // skin) would overflow the minimum-image bound: the clamped
        // cutoff+skin must keep the Verlet builder's assert satisfied
        // all the way down to a 2x2x2 box
        for n_side in [2usize, 4] {
            let (ds, cell) = gen_periodic_lj_dataset(n_side, 1, 0.1, 7);
            assert_eq!(ds.len(), 1);
            assert_eq!(ds[0].n_atoms(), n_side.pow(3));
            assert!(ds[0].energy.is_finite());
            let (m, _, skin) = lj_box_mic(n_side);
            let rc = m.potential.nonbonded_cutoff().unwrap();
            assert!(rc + skin <= cell.max_cutoff() + 1e-9);
        }
    }

    #[test]
    fn normalization_round_trip() {
        let mut ds = gen_bpa_dataset(&[0.05], 4, 3).remove(0);
        let stats = energy_stats(&ds);
        let orig_e: Vec<f64> = ds.iter().map(|g| g.energy).collect();
        normalize_graphs(&mut ds, stats);
        let norm_stats = energy_stats(&ds);
        assert!(norm_stats.mean_per_atom.abs() < 1e-9);
        // invert
        for (g, &e0) in ds.iter_mut().zip(&orig_e) {
            let e = g.energy * stats.std_per_atom
                + g.n_atoms() as f64 * stats.mean_per_atom;
            assert!((e - e0).abs() < 1e-9);
        }
    }
}
