//! gaunt-tp CLI — leader entrypoint.
//!
//! Subcommands (hand-rolled parsing; clap is unavailable offline):
//!   info                      list artifacts + platform
//!   check                     load & smoke-run every artifact
//!   serve [--requests N] [--native]   run the batched force-field
//!                             service demo (--native: no artifacts
//!                             needed, native Gaunt-TP backend)
//!   train --variant {gaunt|cg} [--steps N]   train GauntNet on the
//!                             synthetic adsorbate dataset
//!   experiment <fig1d|table1|table2|tp-throughput>   regenerate a paper
//!                             artifact (tp-throughput runs offline)
//!   loadtest [--requests N] [--clients C] [--workers W] [--global-queue]
//!                             drive the typed Client API with
//!                             concurrent mixed-size traffic through the
//!                             shape-bucketed native service (offline);
//!                             --global-queue serves the single
//!                             worst-case-width queue for comparison
//!   loadtest --net [--replicas M] [--clients C] [--requests N] [--kill-one]
//!                             TRUE multi-process loadtest: spawns M
//!                             replica processes + 1 front door + C
//!                             client processes over unix sockets;
//!                             --kill-one SIGKILLs a replica mid-load
//!   replica --listen ADDR [--workers W] [--name S]   serve one native
//!                             Service over a socket (ADDR = host:port
//!                             or unix:/path)
//!   frontdoor --listen ADDR (--replica ADDR)* [--spawn-replicas N]
//!                             route across replicas; --spawn-replicas
//!                             self-spawns N replica child processes and
//!                             supervises them (dead children respawn
//!                             with bounded backoff and rejoin routing)
//!   net-worker --connect ADDR [--requests N] ...   loadtest client
//!                             process body; prints a NETLOAD ledger
//!   md-demo                   short MD run of the 3BPA-lite molecule

use std::sync::Arc;

use gaunt_tp::coordinator::{NativeGauntBackend, ServerConfig, Service};
use gaunt_tp::err;
use gaunt_tp::experiments;
use gaunt_tp::net::loadtest::{
    run_client_worker, run_cluster_loadtest, LoadOpts,
};
use gaunt_tp::net::{temp_socket_path, Addr, FrontDoor, FrontDoorConfig,
                    Replica, RespawnPolicy};
use gaunt_tp::runtime::Engine;
use gaunt_tp::util::error::Result;

fn arg_value(args: &[String], key: &str) -> Option<String> {
    args.iter()
        .position(|a| a == key)
        .and_then(|i| args.get(i + 1).cloned())
}

/// All values of a repeatable flag (`--replica A --replica B`).
fn arg_values(args: &[String], key: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut i = 0;
    while i + 1 < args.len() {
        if args[i] == key {
            out.push(args[i + 1].clone());
            i += 2;
        } else {
            i += 1;
        }
    }
    out
}

fn parse_addr(s: &str) -> Result<Addr> {
    Addr::parse(s).map_err(|e| err!("{e}"))
}

/// Build the native serving stack used by every socket subcommand.
fn native_service(workers: usize) -> Result<Service> {
    Service::builder()
        .native(NativeGauntBackend::default())
        .config(ServerConfig { n_workers: workers, ..Default::default() })
        .build()
}

fn artifacts_dir(args: &[String]) -> String {
    arg_value(args, "--artifacts").unwrap_or_else(|| "artifacts".to_string())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "info" => {
            let engine = Engine::new(artifacts_dir(&args))?;
            println!("platform: {}", engine.platform());
            let mut names = engine.artifact_names();
            names.sort();
            println!("artifacts ({}):", names.len());
            for n in names {
                println!("  {n}");
            }
            Ok(())
        }
        "check" => {
            let engine = Arc::new(Engine::new(artifacts_dir(&args))?);
            experiments::check_artifacts(&engine)
        }
        "serve" => {
            let n: usize = arg_value(&args, "--requests")
                .and_then(|v| v.parse().ok())
                .unwrap_or(64);
            if args.iter().any(|a| a == "--native") {
                experiments::serve_demo_native(n)
            } else {
                let engine = Arc::new(Engine::new(artifacts_dir(&args))?);
                experiments::serve_demo(engine, n)
            }
        }
        "train" => {
            let variant = arg_value(&args, "--variant")
                .unwrap_or_else(|| "gaunt".to_string());
            let steps: usize = arg_value(&args, "--steps")
                .and_then(|v| v.parse().ok())
                .unwrap_or(200);
            let engine = Arc::new(Engine::new(artifacts_dir(&args))?);
            experiments::train_forcefield(&engine, &variant, steps, true)
                .map(|_| ())
        }
        "experiment" => {
            let which = args
                .get(1)
                .ok_or_else(|| err!("experiment needs a name"))?;
            if which == "tp-throughput" {
                let rows: usize = arg_value(&args, "--rows")
                    .and_then(|v| v.parse().ok())
                    .unwrap_or(256);
                return experiments::tp_throughput(rows);
            }
            let engine = Arc::new(Engine::new(artifacts_dir(&args))?);
            match which.as_str() {
                "fig1d" => experiments::fig1d_sanity_check(&engine),
                "table1" => experiments::table1_oc_analog(&engine),
                "table2" => experiments::table2_bpa_analog(&engine),
                other => Err(err!("unknown experiment '{other}'")),
            }
        }
        "loadtest" => {
            let requests: usize = arg_value(&args, "--requests")
                .and_then(|v| v.parse().ok())
                .unwrap_or(256);
            let clients: usize = arg_value(&args, "--clients")
                .and_then(|v| v.parse().ok())
                .unwrap_or(4);
            let workers: usize = arg_value(&args, "--workers")
                .and_then(|v| v.parse().ok())
                .unwrap_or(2);
            if args.iter().any(|a| a == "--net") {
                let opts = LoadOpts {
                    replicas: arg_value(&args, "--replicas")
                        .and_then(|v| v.parse().ok())
                        .unwrap_or(2),
                    clients,
                    requests_per_client: requests,
                    kill_one: args.iter().any(|a| a == "--kill-one"),
                    workers,
                    ..Default::default()
                };
                let exe = std::env::current_exe()
                    .map_err(|e| err!("current_exe: {e}"))?;
                let report = run_cluster_loadtest(&exe, &opts)
                    .map_err(|e| err!("{e}"))?;
                let t = &report.total;
                println!(
                    "multi-process loadtest: {} replicas x {} clients \
                     ({} req/client){}",
                    opts.replicas,
                    opts.clients,
                    opts.requests_per_client,
                    if report.killed_replica {
                        ", one replica KILLED mid-load"
                    } else {
                        ""
                    }
                );
                println!(
                    "  n={} ok={} rejected={} canceled={} expired={} \
                     failed={}",
                    t.n, t.ok, t.rejected, t.canceled, t.expired, t.failed
                );
                println!(
                    "  success {:.1}%  p50 {:.2} ms  p99 {:.2} ms  wall \
                     {:.2} s",
                    report.success_rate() * 100.0,
                    t.p50_ms,
                    t.p99_ms,
                    report.wall.as_secs_f64()
                );
                if let Some(s) = &report.frontdoor_stats {
                    println!(
                        "  front-door fleet ledger: requests={} \
                         responses={} reconciles={}",
                        s.requests,
                        s.responses,
                        s.reconciles()
                    );
                }
                if !t.reconciles() {
                    return Err(err!(
                        "aggregated client ledger does not reconcile"
                    ));
                }
                return Ok(());
            }
            let bucketed = !args.iter().any(|a| a == "--global-queue");
            experiments::loadtest(requests, clients, workers, bucketed)
        }
        "replica" => {
            let listen = arg_value(&args, "--listen")
                .ok_or_else(|| err!("replica needs --listen ADDR"))?;
            let addr = parse_addr(&listen)?;
            let workers: usize = arg_value(&args, "--workers")
                .and_then(|v| v.parse().ok())
                .unwrap_or(2);
            let name = arg_value(&args, "--name")
                .unwrap_or_else(|| "replica".to_string());
            let replica =
                Replica::serve(native_service(workers)?, &[addr], &name)
                    .map_err(|e| err!("bind: {e}"))?;
            println!("replica '{name}' serving on {}", replica.bound()[0]);
            // serve until killed (the loadtest orchestrator and
            // `make serve-cluster` manage this process's lifetime)
            loop {
                std::thread::park();
            }
        }
        "frontdoor" => {
            let listen = arg_value(&args, "--listen")
                .ok_or_else(|| err!("frontdoor needs --listen ADDR"))?;
            let addr = parse_addr(&listen)?;
            let mut replica_addrs = Vec::new();
            for r in arg_values(&args, "--replica") {
                replica_addrs.push(parse_addr(&r)?);
            }
            let spawn_n: usize = arg_value(&args, "--spawn-replicas")
                .and_then(|v| v.parse().ok())
                .unwrap_or(0);
            // (replica index, child, respawn argv) for supervision
            let mut children = Vec::new();
            if spawn_n > 0 {
                let exe = std::env::current_exe()
                    .map_err(|e| err!("current_exe: {e}"))?;
                for i in 0..spawn_n {
                    let sock = temp_socket_path(&format!("cluster-r{i}"));
                    let raddr = Addr::Unix(sock);
                    let cmd: Vec<String> = vec![
                        exe.to_string_lossy().into_owned(),
                        "replica".to_string(),
                        "--listen".to_string(),
                        raddr.to_string(),
                        "--name".to_string(),
                        format!("r{i}"),
                    ];
                    let child = std::process::Command::new(&cmd[0])
                        .args(&cmd[1..])
                        .spawn()
                        .map_err(|e| err!("spawn replica {i}: {e}"))?;
                    children.push((replica_addrs.len(), child, cmd));
                    replica_addrs.push(raddr);
                }
            }
            if replica_addrs.is_empty() {
                return Err(err!(
                    "frontdoor needs --replica ADDR or --spawn-replicas N"
                ));
            }
            let fd = FrontDoor::serve(
                &replica_addrs,
                &[addr],
                FrontDoorConfig::default(),
            )
            .map_err(|e| err!("bind: {e}"))?;
            // spawned children are supervised: a dead one is respawned
            // with bounded backoff and rejoins via the prober
            for (idx, child, cmd) in children {
                fd.supervise(idx, child, cmd, RespawnPolicy::default());
            }
            println!(
                "front door on {} routing to {} replica(s)",
                fd.bound()[0],
                replica_addrs.len()
            );
            loop {
                std::thread::park();
            }
        }
        "net-worker" => {
            let connect = arg_value(&args, "--connect")
                .ok_or_else(|| err!("net-worker needs --connect ADDR"))?;
            let addr = parse_addr(&connect)?;
            let requests: usize = arg_value(&args, "--requests")
                .and_then(|v| v.parse().ok())
                .unwrap_or(40);
            let concurrency: usize = arg_value(&args, "--concurrency")
                .and_then(|v| v.parse().ok())
                .unwrap_or(4);
            let deadline_ms: u64 = arg_value(&args, "--deadline-ms")
                .and_then(|v| v.parse().ok())
                .unwrap_or(10_000);
            let seed: u64 = arg_value(&args, "--seed")
                .and_then(|v| v.parse().ok())
                .unwrap_or(1);
            let ledger = run_client_worker(
                &addr, requests, concurrency, deadline_ms, seed,
            )
            .map_err(|e| err!("{e}"))?;
            println!("NETLOAD {}", ledger.to_json().to_string());
            Ok(())
        }
        "md-demo" => experiments::md_demo(),
        _ => {
            println!(
                "gaunt-tp — Gaunt Tensor Products (ICLR 2024) reproduction\n\
                 usage: gaunt-tp \
                 <info|check|serve|train|experiment|loadtest|replica|\
                 frontdoor|md-demo> [--artifacts DIR]\n\
                 \x20 serve --requests N [--native]\n\
                 \x20 train --variant gaunt|cg --steps N\n\
                 \x20 experiment fig1d|table1|table2|tp-throughput\n\
                 \x20 loadtest --requests N --clients C --workers W \
                 [--global-queue]\n\
                 \x20 loadtest --net --replicas M --clients C --requests N \
                 [--kill-one]\n\
                 \x20 replica --listen unix:/tmp/r0.sock --workers W \
                 --name r0\n\
                 \x20 frontdoor --listen unix:/tmp/fd.sock \
                 --replica unix:/tmp/r0.sock | --spawn-replicas N"
            );
            Ok(())
        }
    }
}
