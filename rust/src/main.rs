//! gaunt-tp CLI — leader entrypoint.
//!
//! Subcommands (hand-rolled parsing; clap is unavailable offline):
//!   info                      list artifacts + platform
//!   check                     load & smoke-run every artifact
//!   serve [--requests N] [--native]   run the batched force-field
//!                             service demo (--native: no artifacts
//!                             needed, native Gaunt-TP backend)
//!   train --variant {gaunt|cg} [--steps N]   train GauntNet on the
//!                             synthetic adsorbate dataset
//!   experiment <fig1d|table1|table2|tp-throughput>   regenerate a paper
//!                             artifact (tp-throughput runs offline)
//!   loadtest [--requests N] [--clients C] [--workers W] [--global-queue]
//!                             drive the typed Client API with
//!                             concurrent mixed-size traffic through the
//!                             shape-bucketed native service (offline);
//!                             --global-queue serves the single
//!                             worst-case-width queue for comparison
//!   md-demo                   short MD run of the 3BPA-lite molecule

use std::sync::Arc;

use gaunt_tp::err;
use gaunt_tp::experiments;
use gaunt_tp::runtime::Engine;
use gaunt_tp::util::error::Result;

fn arg_value(args: &[String], key: &str) -> Option<String> {
    args.iter()
        .position(|a| a == key)
        .and_then(|i| args.get(i + 1).cloned())
}

fn artifacts_dir(args: &[String]) -> String {
    arg_value(args, "--artifacts").unwrap_or_else(|| "artifacts".to_string())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "info" => {
            let engine = Engine::new(artifacts_dir(&args))?;
            println!("platform: {}", engine.platform());
            let mut names = engine.artifact_names();
            names.sort();
            println!("artifacts ({}):", names.len());
            for n in names {
                println!("  {n}");
            }
            Ok(())
        }
        "check" => {
            let engine = Arc::new(Engine::new(artifacts_dir(&args))?);
            experiments::check_artifacts(&engine)
        }
        "serve" => {
            let n: usize = arg_value(&args, "--requests")
                .and_then(|v| v.parse().ok())
                .unwrap_or(64);
            if args.iter().any(|a| a == "--native") {
                experiments::serve_demo_native(n)
            } else {
                let engine = Arc::new(Engine::new(artifacts_dir(&args))?);
                experiments::serve_demo(engine, n)
            }
        }
        "train" => {
            let variant = arg_value(&args, "--variant")
                .unwrap_or_else(|| "gaunt".to_string());
            let steps: usize = arg_value(&args, "--steps")
                .and_then(|v| v.parse().ok())
                .unwrap_or(200);
            let engine = Arc::new(Engine::new(artifacts_dir(&args))?);
            experiments::train_forcefield(&engine, &variant, steps, true)
                .map(|_| ())
        }
        "experiment" => {
            let which = args
                .get(1)
                .ok_or_else(|| err!("experiment needs a name"))?;
            if which == "tp-throughput" {
                let rows: usize = arg_value(&args, "--rows")
                    .and_then(|v| v.parse().ok())
                    .unwrap_or(256);
                return experiments::tp_throughput(rows);
            }
            let engine = Arc::new(Engine::new(artifacts_dir(&args))?);
            match which.as_str() {
                "fig1d" => experiments::fig1d_sanity_check(&engine),
                "table1" => experiments::table1_oc_analog(&engine),
                "table2" => experiments::table2_bpa_analog(&engine),
                other => Err(err!("unknown experiment '{other}'")),
            }
        }
        "loadtest" => {
            let requests: usize = arg_value(&args, "--requests")
                .and_then(|v| v.parse().ok())
                .unwrap_or(256);
            let clients: usize = arg_value(&args, "--clients")
                .and_then(|v| v.parse().ok())
                .unwrap_or(4);
            let workers: usize = arg_value(&args, "--workers")
                .and_then(|v| v.parse().ok())
                .unwrap_or(2);
            let bucketed = !args.iter().any(|a| a == "--global-queue");
            experiments::loadtest(requests, clients, workers, bucketed)
        }
        "md-demo" => experiments::md_demo(),
        _ => {
            println!(
                "gaunt-tp — Gaunt Tensor Products (ICLR 2024) reproduction\n\
                 usage: gaunt-tp \
                 <info|check|serve|train|experiment|loadtest|md-demo> \
                 [--artifacts DIR]\n\
                 \x20 serve --requests N [--native]\n\
                 \x20 train --variant gaunt|cg --steps N\n\
                 \x20 experiment fig1d|table1|table2|tp-throughput\n\
                 \x20 loadtest --requests N --clients C --workers W \
                 [--global-queue]"
            );
            Ok(())
        }
    }
}
