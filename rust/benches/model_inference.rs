//! Serving-path model inference benchmark: atoms/sec of the full learned
//! force field (energy + analytic forces through every planned Gaunt
//! plan), single-threaded vs all cores, plus the energy-only forward.
//!
//! Feeds the `model` rows of BENCH_fourier.json via
//! `scripts/bench_snapshot.sh`; the multi-thread speedup is the
//! `pool::shard_rows_with` (one scratch per worker) claim measured
//! end to end.
//!
//! `--smoke`: one tiny batch, 1 ms budgets, no TSV (CI liveness check).

use gaunt_tp::data::gen_bpa_dataset;
use gaunt_tp::model::{
    energy_forces_batch_par, GraphRef, Model, ModelConfig,
};
use gaunt_tp::util::bench::{budget_ms, consume, smoke, BenchTable};
use gaunt_tp::util::pool;

fn main() {
    let mut t = BenchTable::new("model inference (learned force field)");
    let n_graphs = if smoke() { 2 } else { 16 };
    let budget = budget_ms(200);
    let graphs_data = gen_bpa_dataset(&[0.05], n_graphs, 5).remove(0);
    let model = Model::new(ModelConfig { r_cut: 3.0, ..Default::default() },
                           7);
    model.warm();
    let edge_lists: Vec<Vec<(usize, usize)>> = graphs_data
        .iter()
        .map(|g| model.build_edges(&g.pos))
        .collect();
    let graphs: Vec<GraphRef<'_>> = graphs_data
        .iter()
        .zip(&edge_lists)
        .map(|(g, edges)| GraphRef {
            pos: &g.pos,
            species: &g.species,
            edges,
            shifts: None,
        })
        .collect();
    let atoms_total: usize = graphs_data.iter().map(|g| g.n_atoms()).sum();

    // energy-only forward, one graph, one scratch (the zero-alloc path)
    {
        let mut scratch = model.scratch();
        let g0 = &graphs[0];
        t.run("model_energy_fwd  1 graph", budget, || {
            consume(model.energy_into(g0.pos, g0.species, g0.edges,
                                      &mut scratch));
        });
        let mut forces = vec![0.0; 3 * g0.pos.len()];
        t.run("model_energy_forces  1 graph", budget, || {
            consume(model.energy_forces_into(
                g0.pos, g0.species, g0.edges, &mut forces, &mut scratch,
            ));
        });
    }

    // batched energy+forces, 1 thread vs all cores
    let mut rates = Vec::new();
    for (label, threads) in [("1 thread", 1usize),
                             ("all cores", 0usize)] {
        let m = gaunt_tp::util::bench::bench(
            &format!("model_batch_B{n_graphs}  {label}"),
            budget,
            || {
                consume(energy_forces_batch_par(&model, &graphs, threads));
            },
        );
        let atoms_per_sec = atoms_total as f64 / (m.median_ns * 1e-9);
        println!("    -> {atoms_per_sec:.0} atoms/sec ({label})");
        rates.push(atoms_per_sec);
        t.add(m);
    }
    if !smoke() {
        println!(
            "batched speedup {:.2}x on {} cores",
            rates[1] / rates[0],
            pool::default_threads()
        );
        t.write_tsv("model_inference");
    }

    // --- multi-channel scaling: atoms/sec of the full batched
    // energy+forces path at 1 / 8 / 32 feature channels (the
    // `multi_channel` section of BENCH_fourier.json) ---
    let mut mc = BenchTable::new("multi_channel: model inference vs channels");
    let chan_set: &[usize] = if smoke() { &[1, 2] } else { &[1, 8, 32] };
    for &channels in chan_set {
        let m = Model::new(
            ModelConfig { r_cut: 3.0, channels, ..Default::default() },
            7,
        );
        m.warm();
        let edge_lists: Vec<Vec<(usize, usize)>> = graphs_data
            .iter()
            .map(|g| m.build_edges(&g.pos))
            .collect();
        let graphs: Vec<GraphRef<'_>> = graphs_data
            .iter()
            .zip(&edge_lists)
            .map(|(g, edges)| GraphRef {
                pos: &g.pos,
                species: &g.species,
                edges,
                shifts: None,
            })
            .collect();
        let meas = gaunt_tp::util::bench::bench(
            &format!("model_batch_B{n_graphs}  C={channels}"),
            budget,
            || {
                consume(energy_forces_batch_par(&m, &graphs, 0));
            },
        );
        let atoms_per_sec = atoms_total as f64 / (meas.median_ns * 1e-9);
        println!("    -> {atoms_per_sec:.0} atoms/sec (C={channels})");
        mc.add(meas);
    }
    if smoke() {
        println!("[smoke] model_inference OK ({} + {} rows)",
                 t.rows.len(), mc.rows.len());
    } else {
        mc.write_tsv("multi_channel");
    }
}
