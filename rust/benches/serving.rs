//! Serving-protocol benchmark: p50/p99 request latency and
//! structures/sec of the typed `Client` -> `Service` path under a
//! bimodal (small/large structure) closed-loop load, comparing the
//! pre-redesign single worst-case-width queue ("global") against
//! shape-bucketed batching ("bucketed") at 1 and N workers.
//!
//! Feeds the `serving` section of BENCH_fourier.json via
//! `scripts/bench_snapshot.sh`.  Derived rows (iters = 0) follow the
//! table2 convention: `*_p50` / `*_p99` carry nanoseconds in
//! `median_ns`; `*_rate` carries structures/sec; `*_atom_fill` carries
//! the executed-slot fill ratio (higher = less padding waste).
//!
//! `--smoke`: a handful of requests, no TSV (CI liveness check).

use std::sync::Arc;
use std::time::{Duration, Instant};

use gaunt_tp::coordinator::batcher::{BatchPolicy, BucketConfig};
use gaunt_tp::coordinator::request::{
    EnergyForces, Request, ServiceError, Structure,
};
use gaunt_tp::coordinator::server::{NativeGauntBackend, ServerConfig};
use gaunt_tp::coordinator::Service;
use gaunt_tp::net::{
    temp_socket_path, Addr, FrontDoor, FrontDoorConfig, NetClient, Replica,
};
use gaunt_tp::util::bench::{smoke, BenchTable, Measurement};
use gaunt_tp::util::pool;
use gaunt_tp::util::rng::Rng;

fn cluster(n: usize, seed: u64) -> Structure {
    let mut rng = Rng::new(seed);
    Structure::new(
        (0..n)
            .map(|i| {
                [
                    3.5 * (i % 3) as f64 + 0.1 * rng.normal(),
                    3.5 * ((i / 3) % 3) as f64 + 0.1 * rng.normal(),
                    3.5 * (i / 9) as f64 + 0.1 * rng.normal(),
                ]
            })
            .collect(),
        (0..n).map(|i| i % 3).collect(),
    )
}

fn derived(name: String, value: f64) -> Measurement {
    Measurement { name, median_ns: value, mad_ns: 0.0, iters: 0 }
}

fn run_config(
    t: &mut BenchTable, label: &str, buckets: Vec<BucketConfig>,
    n_workers: usize, n_requests: usize, structures: &[Structure],
) {
    let policy = BatchPolicy {
        max_batch: 8,
        max_wait: Duration::from_millis(2),
        max_queue: 65536,
    };
    let service = Service::builder()
        .native(NativeGauntBackend::default())
        .config(ServerConfig { policy, n_workers, ..Default::default() })
        .buckets(buckets)
        .build()
        .expect("native service");
    let client = service.client();
    // closed loop from two submitter threads (keeps the queue non-empty
    // without unbounded pile-up)
    let t0 = Instant::now();
    let mut lat: Vec<f64> = Vec::with_capacity(n_requests);
    let mut handles = Vec::new();
    for c in 0..2usize {
        let client = client.clone();
        let structs: Vec<Structure> = structures.to_vec();
        let per = n_requests / 2;
        handles.push(std::thread::spawn(move || -> Vec<f64> {
            let mut lat = Vec::with_capacity(per);
            for k in 0..per {
                let st = structs[(2 * k + c) % structs.len()].clone();
                match client
                    .submit(Request::new(EnergyForces(st)))
                    .map(|t| t.wait())
                {
                    Ok(Ok(resp)) => lat.push(resp.latency_s),
                    _ => {}
                }
            }
            lat
        }));
    }
    for h in handles {
        lat.extend(h.join().unwrap());
    }
    let wall = t0.elapsed().as_secs_f64();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    assert!(!lat.is_empty(), "no request completed");
    let n = lat.len();
    let p50_ns = 1e9 * lat[n / 2];
    let p99_ns = 1e9 * lat[(n * 99 / 100).min(n - 1)];
    let rate = n as f64 / wall;
    let fill = service.metrics().atom_fill();
    t.add(derived(format!("serving_{label}_w{n_workers}_p50"), p50_ns));
    t.add(derived(format!("serving_{label}_w{n_workers}_p99"), p99_ns));
    t.add(derived(format!("serving_{label}_w{n_workers}_rate"), rate));
    t.add(derived(
        format!("serving_{label}_w{n_workers}_atom_fill"),
        fill,
    ));
    service.shutdown();
}

/// Resilience profile: p99 + success rate of the SAME small-queue
/// service when politely loaded vs ~2x oversubscribed.  Under overload
/// the admission controller sheds typed `Overloaded` instead of letting
/// the queue (and the p99 of admitted work) grow without bound; the
/// shed fraction is reported alongside so a regression that "improves"
/// success by queueing forever is visible.  Runs with no failpoints
/// armed — this is the production-code path.
fn run_resilience(
    t: &mut BenchTable, label: &str, submitters: usize, n_per: usize,
    structures: &[Structure],
) {
    let policy = BatchPolicy {
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        max_queue: 8,
    };
    let service = Service::builder()
        .native(NativeGauntBackend::default())
        .config(ServerConfig { policy, n_workers: 2, ..Default::default() })
        .buckets(vec![BucketConfig { max_atoms: 32, max_edges: 256, policy }])
        .build()
        .expect("native service");
    let client = service.client();
    let mut handles = Vec::new();
    for c in 0..submitters {
        let client = client.clone();
        let structs: Vec<Structure> = structures.to_vec();
        // (latencies of completed requests, attempts, sheds)
        handles.push(std::thread::spawn(move || -> (Vec<f64>, usize, usize) {
            let mut lat = Vec::with_capacity(n_per);
            let mut sheds = 0usize;
            for k in 0..n_per {
                let st = structs[(submitters * k + c) % structs.len()].clone();
                match client.submit(Request::new(EnergyForces(st))) {
                    Ok(ticket) => {
                        if let Ok(resp) = ticket.wait() {
                            lat.push(resp.latency_s);
                        }
                    }
                    Err(ServiceError::Overloaded { retry_after }) => {
                        sheds += 1;
                        std::thread::sleep(retry_after);
                    }
                    Err(_) => {}
                }
            }
            (lat, n_per, sheds)
        }));
    }
    let mut lat: Vec<f64> = Vec::new();
    let mut attempts = 0usize;
    let mut sheds = 0usize;
    for h in handles {
        let (l, a, s) = h.join().unwrap();
        lat.extend(l);
        attempts += a;
        sheds += s;
    }
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    assert!(!lat.is_empty(), "no request completed under {label}");
    let n = lat.len();
    let p99_ns = 1e9 * lat[(n * 99 / 100).min(n - 1)];
    t.add(derived(format!("resilience_{label}_p99"), p99_ns));
    t.add(derived(
        format!("resilience_{label}_success"),
        n as f64 / attempts as f64,
    ));
    t.add(derived(
        format!("resilience_{label}_shed_frac"),
        sheds as f64 / attempts as f64,
    ));
    service.shutdown();
}

fn socket_service(n_workers: usize) -> Service {
    Service::builder()
        .native(NativeGauntBackend::default())
        .config(ServerConfig { n_workers, ..Default::default() })
        .build()
        .expect("native service")
}

/// Closed-loop p50/p99/rate of `submit` through a caller-supplied
/// transport — the measured latency is the full client-side round trip,
/// so the in-process row and the socket rows compare apples-to-apples.
fn run_transport(
    t: &mut BenchTable, label: &str, n_requests: usize,
    structures: &[Structure],
    submit_wait: Arc<dyn Fn(Structure) -> bool + Send + Sync>,
) {
    let t0 = Instant::now();
    let mut lat: Vec<f64> = Vec::with_capacity(n_requests);
    let mut handles = Vec::new();
    for c in 0..2usize {
        let submit_wait = submit_wait.clone();
        let structs: Vec<Structure> = structures.to_vec();
        let per = n_requests / 2;
        handles.push(std::thread::spawn(move || -> Vec<f64> {
            let mut lat = Vec::with_capacity(per);
            for k in 0..per {
                let st = structs[(2 * k + c) % structs.len()].clone();
                let r0 = Instant::now();
                if submit_wait(st) {
                    lat.push(r0.elapsed().as_secs_f64());
                }
            }
            lat
        }));
    }
    for h in handles {
        lat.extend(h.join().unwrap());
    }
    let wall = t0.elapsed().as_secs_f64();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    assert!(!lat.is_empty(), "no request completed over {label}");
    let n = lat.len();
    t.add(derived(format!("socket_{label}_p50"), 1e9 * lat[n / 2]));
    t.add(derived(
        format!("socket_{label}_p99"),
        1e9 * lat[(n * 99 / 100).min(n - 1)],
    ));
    t.add(derived(format!("socket_{label}_rate"), n as f64 / wall));
}

/// The socket section: the SAME closed-loop workload through (a) the
/// in-process typed client, (b) one replica over a Unix socket, (c) one
/// replica over TCP loopback, (d) a front door sharding N replicas over
/// Unix sockets.  The deltas price the wire hop (frame + JSON codec +
/// syscalls) and show what replica sharding buys back.
fn run_socket_section(
    t: &mut BenchTable, n_requests: usize, structures: &[Structure],
    n_replicas: usize,
) {
    // (a) in-process baseline
    {
        let service = socket_service(2);
        let client = service.client();
        let f = {
            let client = client.clone();
            Arc::new(move |st: Structure| {
                client
                    .submit(Request::new(EnergyForces(st)))
                    .map(|tk| tk.wait().is_ok())
                    .unwrap_or(false)
            })
        };
        run_transport(t, "inproc", n_requests, structures, f);
        service.shutdown();
    }
    // (b) one replica, Unix socket
    {
        let replica = Replica::serve(
            socket_service(2),
            &[Addr::Unix(temp_socket_path("bench-unix"))],
            "bench-unix",
        )
        .expect("bind unix replica");
        let nc =
            Arc::new(NetClient::connect(&replica.bound()[0]).expect("connect"));
        let f = {
            let nc = nc.clone();
            Arc::new(move |st: Structure| {
                nc.submit(Request::new(EnergyForces(st)))
                    .map(|tk| tk.wait().is_ok())
                    .unwrap_or(false)
            })
        };
        run_transport(t, "unix_r1", n_requests, structures, f);
        nc.close();
        replica.shutdown();
    }
    // (c) one replica, TCP loopback
    {
        let replica = Replica::serve(
            socket_service(2),
            &[Addr::Tcp("127.0.0.1:0".to_string())],
            "bench-tcp",
        )
        .expect("bind tcp replica");
        let nc =
            Arc::new(NetClient::connect(&replica.bound()[0]).expect("connect"));
        let f = {
            let nc = nc.clone();
            Arc::new(move |st: Structure| {
                nc.submit(Request::new(EnergyForces(st)))
                    .map(|tk| tk.wait().is_ok())
                    .unwrap_or(false)
            })
        };
        run_transport(t, "tcp_r1", n_requests, structures, f);
        nc.close();
        replica.shutdown();
    }
    // (d) front door over N replicas, Unix sockets
    {
        let replicas: Vec<Replica> = (0..n_replicas)
            .map(|i| {
                Replica::serve(
                    socket_service(2),
                    &[Addr::Unix(temp_socket_path(&format!("bench-fd-r{i}")))],
                    &format!("bench-r{i}"),
                )
                .expect("bind fd replica")
            })
            .collect();
        let addrs: Vec<Addr> =
            replicas.iter().map(|r| r.bound()[0].clone()).collect();
        let fd = FrontDoor::serve(
            &addrs,
            &[Addr::Unix(temp_socket_path("bench-fd"))],
            FrontDoorConfig::default(),
        )
        .expect("front door up");
        let nc = Arc::new(NetClient::connect(&fd.bound()[0]).expect("connect"));
        let f = {
            let nc = nc.clone();
            Arc::new(move |st: Structure| {
                nc.submit(Request::new(EnergyForces(st)))
                    .map(|tk| tk.wait().is_ok())
                    .unwrap_or(false)
            })
        };
        run_transport(
            t,
            &format!("unix_r{n_replicas}_fd"),
            n_requests,
            structures,
            f,
        );
        nc.close();
        fd.shutdown();
        for r in replicas {
            r.shutdown();
        }
    }
}

fn main() {
    let mut t = BenchTable::new(
        "serving protocol: global queue vs shape-bucketed batching",
    );
    let n_requests = if smoke() { 16 } else { 512 };
    // bimodal: 4-atom and 24-atom structures, interleaved
    let mut structures = Vec::new();
    for k in 0..8u64 {
        structures.push(cluster(4, 100 + k));
        structures.push(cluster(24, 200 + k));
    }
    let policy = BatchPolicy {
        max_batch: 8,
        max_wait: Duration::from_millis(2),
        max_queue: 65536,
    };
    let global = vec![BucketConfig { max_atoms: 32, max_edges: 256, policy }];
    let bucketed = vec![
        BucketConfig { max_atoms: 8, max_edges: 56, policy },
        BucketConfig { max_atoms: 32, max_edges: 256, policy },
    ];
    let n_cores = pool::default_threads().max(2);
    for workers in [1usize, n_cores] {
        run_config(
            &mut t, "global_q", global.clone(), workers, n_requests,
            &structures,
        );
        run_config(
            &mut t, "bucketed", bucketed.clone(), workers, n_requests,
            &structures,
        );
    }
    if !smoke() {
        t.write_tsv("serving");
    }

    // resilience: the same bimodal mix through a small-queue service,
    // politely (2 closed-loop submitters vs 2 workers) and then ~2x
    // oversubscribed (8 submitters against an 8-deep queue)
    let mut r = BenchTable::new(
        "resilience: admission control under overload (typed shedding)",
    );
    let n_per = if smoke() { 8 } else { 128 };
    run_resilience(&mut r, "healthy", 2, n_per, &structures);
    run_resilience(&mut r, "overload", 8, n_per, &structures);
    if !smoke() {
        r.write_tsv("resilience");
    }

    // socket section: the wire-hop tax (in-process vs unix vs TCP
    // loopback) and the sharding payback (front door over N replicas)
    let mut s = BenchTable::new(
        "socket serving: in-process vs unix vs tcp, 1 vs N replicas",
    );
    let n_socket = if smoke() { 12 } else { 256 };
    let n_replicas = pool::default_threads().clamp(2, 4);
    run_socket_section(&mut s, n_socket, &structures, n_replicas);
    if !smoke() {
        s.write_tsv("socket");
    }
}
