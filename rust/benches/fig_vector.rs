//! Vector-signal Gaunt products — `tp::vector` scaling (DESIGN.md §15).
//!
//! The three vector operators (scalar x vector, vector . vector,
//! vector x vector) route Cartesian components through the scalar
//! sh2f -> conv -> f2sh pipeline, so each costs a small constant
//! multiple of the scalar Gaunt product: O(L^3) overall.  The baseline
//! is [`NaiveVectorTp`], the dense Gaunt-tensor contraction (O(L^6))
//! the conformance tests oracle against — the same planned-vs-dense
//! comparison Fig. 1 makes for scalar signals, here for vector ones.
//!
//! Rows per degree: naive dense, planned direct conv, planned FFT, for
//! each kind; a `speedup` line per degree summarizes planned-best over
//! naive.  `--smoke`: one tiny size, 1 ms budgets, no TSV.

use gaunt_tp::num_coeffs;
use gaunt_tp::tp::{ConvMethod, NaiveVectorTp, VectorGauntPlan, VectorKind};
use gaunt_tp::util::bench::{budget_ms, consume, fmt_ns, smoke, BenchTable};
use gaunt_tp::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(7);
    let mut t = BenchTable::new(
        "fig_vector: vector-signal Gaunt products, planned vs dense",
    );
    let ls: &[usize] = if smoke() { &[2] } else { &[1, 2, 3, 4, 6, 8] };
    let budget = budget_ms(150);
    let kinds = [
        VectorKind::ScalarVector,
        VectorKind::VectorDot,
        VectorKind::VectorCross,
    ];
    for &l in ls {
        let nf = num_coeffs(l);
        let mut best_planned = f64::INFINITY;
        let mut naive_ns = f64::INFINITY;
        for kind in kinds {
            let plan = VectorGauntPlan::new(kind, l, l, l, ConvMethod::Direct);
            let (n1, n2, n3) = plan.dims();
            let x1 = rng.normals(n1);
            let x2 = rng.normals(n2);
            // dense Gaunt-tensor baseline: build cost excluded, the
            // contraction itself is the O(L^6) story.  Degrees past 6
            // take whole seconds per call; skip the naive row there.
            if l <= 6 {
                let naive = NaiveVectorTp::new(kind, l, l, l);
                let m = gaunt_tp::util::bench::bench(
                    &format!("naive_dense  {:<5} L={l}", kind.name()),
                    budget,
                    || {
                        consume(naive.apply(&x1, &x2));
                    },
                );
                naive_ns = naive_ns.min(m.median_ns);
                t.add(m);
            }
            for method in [ConvMethod::Direct, ConvMethod::Fft] {
                let plan = VectorGauntPlan::new(kind, l, l, l, method);
                let mut out = vec![0.0; n3];
                let mut scratch = plan.scratch();
                let label = match method {
                    ConvMethod::Fft => "plan_fft",
                    _ => "plan_direct",
                };
                let m = gaunt_tp::util::bench::bench(
                    &format!("{label:<12} {:<5} L={l}", kind.name()),
                    budget,
                    || {
                        plan.apply_into(&x1, &x2, &mut out, &mut scratch);
                        consume(&out);
                    },
                );
                best_planned = best_planned.min(m.median_ns);
                t.add(m);
            }
        }
        if naive_ns.is_finite() {
            println!(
                "  -> L={l} (nf={nf}): fastest planned {} vs fastest naive \
                 {}  ({:.1}x)",
                fmt_ns(best_planned),
                fmt_ns(naive_ns),
                naive_ns / best_planned
            );
        }
    }
    if smoke() {
        println!("[smoke] fig_vector OK ({} rows)", t.rows.len());
    } else {
        t.write_tsv("fig_vector");
    }
}
