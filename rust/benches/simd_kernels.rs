//! SIMD hot-path kernels: each vectorized kernel against the scalar
//! oracle it replaced, with explicit `speedup_*` ratio rows in the TSV
//! (derived rows carry the ratio in `median_ns` with mad 0, iters 0 —
//! the same convention as `table2_speed_memory`).
//!
//! Three kernels from the Fourier hot path (butterflies, pointwise
//! spectral product, f2sh back-projection) plus the cache-blocked
//! column pass of the 2D FFT.  On f64 every SIMD path is bit-identical
//! to its oracle (`tests/simd_conformance.rs`), so these ratios measure
//! pure speed, never a numeric trade.
//!
//! `--smoke`: one tiny size per kernel, 1 ms budgets, no TSV.

use gaunt_tp::fourier::{
    f2sh_contract, f2sh_contract_scalar, C64, F2shPanelsT, FftPlan,
    COL_BLOCK,
};
use gaunt_tp::num_coeffs;
use gaunt_tp::util::bench::{bench, budget_ms, consume, smoke, BenchTable,
                            Measurement};
use gaunt_tp::util::rng::Rng;
use gaunt_tp::util::simd::ACTIVE_IMPL;

fn ratio_row(t: &mut BenchTable, name: String, before: f64, after: f64) {
    t.add(Measurement {
        name,
        median_ns: before / after,
        mad_ns: 0.0,
        iters: 0,
    });
}

fn main() {
    let budget = budget_ms(200);
    let mut rng = Rng::new(0);
    println!("active SIMD implementation: {ACTIVE_IMPL}");

    let mut t = BenchTable::new("simd kernels: vectorized vs scalar oracle");

    // 1. 1D FFT butterflies ------------------------------------------------
    let fft_sizes: &[usize] = if smoke() { &[64] } else { &[64, 256, 1024] };
    for &n in fft_sizes {
        let plan = FftPlan::shared(n);
        let data: Vec<C64> = (0..n)
            .map(|_| C64::new(rng.normal(), rng.normal()))
            .collect();
        let mut buf = data.clone();
        let m_scalar = bench(&format!("fft_scalar n={n}"), budget, || {
            buf.copy_from_slice(&data);
            plan.process_scalar(&mut buf, false);
            consume(&buf);
        });
        t.add(m_scalar.clone());
        let m_simd = bench(&format!("fft_simd   n={n}"), budget, || {
            buf.copy_from_slice(&data);
            plan.process(&mut buf, false);
            consume(&buf);
        });
        t.add(m_simd.clone());
        ratio_row(
            &mut t,
            format!("speedup_fft n={n}"),
            m_scalar.median_ns,
            m_simd.median_ns,
        );
    }

    // 2. pointwise spectral product ---------------------------------------
    // the ConvPlan inner loop in isolation: scalar C64 multiply vs the
    // lane complex_mul over the same interleaved buffers
    let pw_sizes: &[usize] = if smoke() { &[256] } else { &[256, 4096, 65536] };
    for &len in pw_sizes {
        let a0: Vec<C64> = (0..len)
            .map(|_| C64::new(rng.normal(), rng.normal()))
            .collect();
        let b: Vec<C64> = (0..len)
            .map(|_| C64::new(rng.normal(), rng.normal()))
            .collect();
        let mut a = a0.clone();
        let m_scalar = bench(&format!("pointwise_scalar len={len}"), budget, || {
            a.copy_from_slice(&a0);
            for (x, y) in a.iter_mut().zip(&b) {
                *x = *x * *y;
            }
            consume(&a);
        });
        t.add(m_scalar.clone());
        let m_simd = bench(&format!("pointwise_simd   len={len}"), budget, || {
            use gaunt_tp::fourier::{as_floats, as_floats_mut};
            use gaunt_tp::util::simd::{F64x4, SimdLanes};
            a.copy_from_slice(&a0);
            let af = as_floats_mut(&mut a);
            let bf = as_floats(&b);
            let mut p = 0;
            while p + 4 <= af.len() {
                let av = F64x4::load(&af[p..]);
                let bv = F64x4::load(&bf[p..]);
                av.complex_mul(bv).store(&mut af[p..]);
                p += 4;
            }
            consume(&a);
        });
        t.add(m_simd.clone());
        ratio_row(
            &mut t,
            format!("speedup_pointwise len={len}"),
            m_scalar.median_ns,
            m_simd.median_ns,
        );
    }

    // 3. f2sh back-projection ---------------------------------------------
    let f2sh_cases: &[(usize, usize)] =
        if smoke() { &[(2, 4)] } else { &[(2, 4), (4, 8), (6, 12), (8, 16)] };
    for &(l_out, n_grid) in f2sh_cases {
        let nu = 2 * n_grid + 1;
        let grid: Vec<C64> = (0..nu * nu)
            .map(|_| C64::new(rng.normal(), rng.normal()))
            .collect();
        let t3t = F2shPanelsT::build(l_out, n_grid);
        let mut out = vec![0.0; num_coeffs(l_out)];
        let m_scalar =
            bench(&format!("f2sh_scalar L={l_out} N={n_grid}"), budget, || {
                f2sh_contract_scalar(&t3t, &grid, &mut out);
                consume(&out);
            });
        t.add(m_scalar.clone());
        let m_simd =
            bench(&format!("f2sh_simd   L={l_out} N={n_grid}"), budget, || {
                f2sh_contract(&t3t, &grid, &mut out);
                consume(&out);
            });
        t.add(m_simd.clone());
        ratio_row(
            &mut t,
            format!("speedup_f2sh L={l_out}"),
            m_scalar.median_ns,
            m_simd.median_ns,
        );
    }

    // 4. cache-blocked 2D FFT column pass ---------------------------------
    // same fft2_inplace entry, scratch sized for block=1 (the old
    // column-at-a-time behavior) vs block=COL_BLOCK
    let fft2_sizes: &[usize] = if smoke() { &[16] } else { &[16, 64, 256] };
    for &n in fft2_sizes {
        let plan = FftPlan::shared(n);
        let grid0: Vec<C64> = (0..n * n)
            .map(|_| C64::new(rng.normal(), rng.normal()))
            .collect();
        let mut grid = grid0.clone();
        let mut col1 = vec![C64::default(); n];
        let mut colb = vec![C64::default(); n * COL_BLOCK];
        let m_one = bench(&format!("fft2_colx1 n={n}"), budget, || {
            grid.copy_from_slice(&grid0);
            plan.fft2_inplace(&mut grid, false, &mut col1);
            consume(&grid);
        });
        t.add(m_one.clone());
        let m_blk = bench(&format!("fft2_colx{COL_BLOCK} n={n}"), budget, || {
            grid.copy_from_slice(&grid0);
            plan.fft2_inplace(&mut grid, false, &mut colb);
            consume(&grid);
        });
        t.add(m_blk.clone());
        ratio_row(
            &mut t,
            format!("speedup_colblock n={n}"),
            m_one.median_ns,
            m_blk.median_ns,
        );
    }

    if !smoke() {
        t.write_tsv("simd_kernels");
    } else {
        println!("[smoke] simd_kernels OK ({} rows)", t.rows.len());
    }
}
