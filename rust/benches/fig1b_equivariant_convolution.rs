//! Fig. 1 panel 2 — Equivariant Convolution efficiency.
//!
//! Feature (x) spherical-harmonic filter per edge: the eSCN SO(2)
//! restriction baseline vs the paper's Gaunt pipeline with the aligned-
//! filter (single Fourier column) speed-up.  Aligned-frame numbers isolate
//! the contraction cost (the rotation round trip is common to both); the
//! `+rot` rows include it.

use gaunt_tp::num_coeffs;
use gaunt_tp::tp::engine::{escn_apply_batch_par, PlanCache};
use gaunt_tp::tp::escn::{EscnPlan, GauntConvPlan};
use gaunt_tp::tp::{CgPlan, ConvMethod, GauntPlan};
use gaunt_tp::so3::sh::real_sh_all_xyz;
use gaunt_tp::util::bench::{consume, BenchTable};
use gaunt_tp::util::pool;
use gaunt_tp::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(0);
    let mut t = BenchTable::new("fig1b: equivariant convolution (per edge)");
    for l in [1usize, 2, 3, 4, 5, 6] {
        let n = num_coeffs(l);
        let x = rng.normals(n);
        let dir = rng.unit3();

        // naive e3nn-style: full CG contraction with the full SH filter
        let cg = CgPlan::new(l, l, l);
        let ysh = real_sh_all_xyz(l, dir);
        t.run(&format!("e3nn_full_filter  L={l}"), 100, || {
            consume(cg.apply_sparse(&x, &ysh));
        });

        // eSCN: aligned-frame SO(2) contraction
        let escn = EscnPlan::new(l, l, l);
        let h: Vec<f64> = (0..escn.n_paths()).map(|_| 1.0).collect();
        t.run(&format!("escn_aligned      L={l}"), 100, || {
            consume(escn.apply_aligned(&x, &h));
        });
        t.run(&format!("escn_aligned+rot  L={l}"), 100, || {
            consume(escn.apply(&x, dir, &h));
        });

        // Gaunt conv: aligned filter => single-column convolution
        let gconv = GauntConvPlan::new(l, l, l);
        let h2: Vec<f64> = (0..=l).map(|_| 1.0).collect();
        t.run(&format!("gaunt_conv        L={l}"), 100, || {
            consume(gconv.apply_aligned(&x, &h2));
        });
        t.run(&format!("gaunt_conv+rot    L={l}"), 100, || {
            consume(gconv.apply(&x, dir, &h2));
        });

        // Gaunt without the eSCN sparsity (full filter through the plan)
        let gfull = GauntPlan::new(l, l, l, ConvMethod::Auto);
        t.run(&format!("gaunt_full_filter L={l}"), 100, || {
            consume(gfull.apply(&x, &ysh));
        });
    }

    // batched edge convolution through the engine: a realistic message-
    // passing layer convolves many edges at once — single-thread vs the
    // sharded worker pool over cached plans
    let threads = pool::default_threads();
    let edges = 64usize;
    let cache = PlanCache::global();
    for l in [2usize, 4] {
        let n = num_coeffs(l);
        let escn = cache.escn(l, l, l);
        let h: Vec<f64> = (0..escn.n_paths()).map(|_| 1.0).collect();
        let xs = rng.normals(edges * n);
        let dirs: Vec<[f64; 3]> = (0..edges).map(|_| rng.unit3()).collect();
        t.run(&format!("escn_batch        L={l} E={edges} x1"), 100, || {
            consume(escn.apply_batch(&xs, &dirs, &h));
        });
        t.run(
            &format!("escn_batch_par    L={l} E={edges} x{threads}"),
            100,
            || {
                consume(escn_apply_batch_par(&escn, &xs, &dirs, &h, 0));
            },
        );
    }
    t.write_tsv("fig1b");
}
