//! Fig. 1 panel 2 — Equivariant Convolution efficiency.
//!
//! Feature (x) spherical-harmonic filter per edge: the eSCN SO(2)
//! restriction baseline vs the paper's Gaunt pipeline with the aligned-
//! filter (single Fourier column) speed-up.  Aligned-frame numbers isolate
//! the contraction cost (the rotation round trip is common to both); the
//! `+rot` rows include it.
//!
//! `gaunt_conv_fft` exercises the plan-cached filter-spectrum path (the
//! filter is never transformed at apply time) against the direct
//! single-column sweep — the measurement behind
//! `escn::GAUNT_CONV_FFT_CROSSOVER`.
//!
//! `--smoke`: one tiny size, 1 ms budgets, no TSV (CI liveness check).

use gaunt_tp::num_coeffs;
use gaunt_tp::tp::engine::PlanCache;
use gaunt_tp::tp::op::{apply_batch_par, BatchInputs};
use gaunt_tp::tp::escn::{EscnPlan, GauntConvPlan};
use gaunt_tp::tp::{CgPlan, ConvMethod, GauntPlan};
use gaunt_tp::so3::sh::real_sh_all_xyz;
use gaunt_tp::util::bench::{budget_ms, consume, smoke, BenchTable};
use gaunt_tp::util::pool;
use gaunt_tp::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(0);
    let mut t = BenchTable::new("fig1b: equivariant convolution (per edge)");
    let ls: &[usize] = if smoke() { &[2] } else { &[1, 2, 3, 4, 5, 6] };
    let budget = budget_ms(100);
    for &l in ls {
        let n = num_coeffs(l);
        let x = rng.normals(n);
        let dir = rng.unit3();

        // naive e3nn-style: full CG contraction with the full SH filter
        let cg = CgPlan::new(l, l, l);
        let ysh = real_sh_all_xyz(l, dir);
        t.run(&format!("e3nn_full_filter  L={l}"), budget, || {
            consume(cg.apply_sparse(&x, &ysh));
        });

        // eSCN: aligned-frame SO(2) contraction
        let escn = EscnPlan::new(l, l, l);
        let h: Vec<f64> = (0..escn.n_paths()).map(|_| 1.0).collect();
        t.run(&format!("escn_aligned      L={l}"), budget, || {
            consume(escn.apply_aligned(&x, &h));
        });
        t.run(&format!("escn_aligned+rot  L={l}"), budget, || {
            consume(escn.apply(&x, dir, &h));
        });

        // Gaunt conv: aligned filter => single-column convolution, vs the
        // cached-filter-spectrum FFT evaluation of the same contraction
        // (both over a held scratch, so the rows measure compute, not
        // allocator traffic)
        let gconv = GauntConvPlan::new(l, l, l);
        let h2: Vec<f64> = (0..=l).map(|_| 1.0).collect();
        let mut gscratch = gconv.scratch();
        let mut gout = vec![0.0; n];
        t.run(&format!("gaunt_conv        L={l}"), budget, || {
            gconv.apply_aligned_direct_into(&x, &h2, &mut gout, &mut gscratch);
            consume(&gout);
        });
        t.run(&format!("gaunt_conv_fft    L={l}"), budget, || {
            gconv.apply_aligned_fft_into(&x, &h2, &mut gout, &mut gscratch);
            consume(&gout);
        });
        t.run(&format!("gaunt_conv+rot    L={l}"), budget, || {
            consume(gconv.apply_with(&x, dir, &h2, &mut gscratch));
        });

        // Gaunt without the eSCN sparsity (full filter through the plan)
        let gfull = GauntPlan::new(l, l, l, ConvMethod::Auto);
        t.run(&format!("gaunt_full_filter L={l}"), budget, || {
            consume(gfull.apply(&x, &ysh));
        });
    }

    // batched edge convolution through the engine: a realistic message-
    // passing layer convolves many edges at once — single-thread vs the
    // sharded worker pool over cached plans
    if !smoke() {
        let threads = pool::default_threads();
        let edges = 64usize;
        let cache = PlanCache::global();
        for l in [2usize, 4] {
            let n = num_coeffs(l);
            let escn = cache.escn(l, l, l);
            let h: Vec<f64> = (0..escn.n_paths()).map(|_| 1.0).collect();
            let xs = rng.normals(edges * n);
            let dirs: Vec<[f64; 3]> = (0..edges).map(|_| rng.unit3()).collect();
            t.run(&format!("escn_batch        L={l} E={edges} x1"), budget, || {
                consume(escn.apply_batch(&xs, &dirs, &h));
            });
            t.run(
                &format!("escn_batch_par    L={l} E={edges} x{threads}"),
                budget,
                || {
                    consume(apply_batch_par(
                        escn.as_ref(), &BatchInputs::edges(&xs, &dirs, &h),
                        edges, 0,
                    ));
                },
            );
            let gconv = cache.gaunt_conv(l, l, l);
            let h2: Vec<f64> = (0..=l).map(|_| 1.0).collect();
            t.run(
                &format!("gaunt_conv_par    L={l} E={edges} x{threads}"),
                budget,
                || {
                    consume(apply_batch_par(
                        gconv.as_ref(), &BatchInputs::edges(&xs, &dirs, &h2),
                        edges, 0,
                    ));
                },
            );
        }
    }
    if smoke() {
        println!("[smoke] fig1b OK ({} rows)", t.rows.len());
    } else {
        t.write_tsv("fig1b");
    }
}
