//! Fig. 1 panel 1 — Equivariant Feature Interaction efficiency.
//!
//! Full tensor product of two features of degree up to L: the e3nn-style
//! Clebsch-Gordan baseline (dense + sparse O(L^6)) vs the paper's Gaunt
//! Tensor Product (O(L^3), direct-conv and FFT variants).  The paper
//! reports GPU wallclock; we reproduce the *scaling shape and crossovers*
//! on CPU (DESIGN.md §3), plus the end-to-end compiled (Pallas->XLA)
//! kernels where artifacts exist.
//!
//! Before/after rows for the Fourier plan layer: `gaunt_fft_legacy` is
//! the allocating sh2f -> conv2d_fft -> f2sh pipeline (the pre-plan
//! implementation), `gaunt_fft` the planned Hermitian path.
//!
//! `--smoke`: one tiny size, 1 ms budgets, no TSV (CI liveness check).

use gaunt_tp::fourier::conv::conv2d_fft;
use gaunt_tp::fourier::tables::sh2f_panels;
use gaunt_tp::num_coeffs;
use gaunt_tp::runtime::{Engine, Tensor};
use gaunt_tp::tp::engine::PlanCache;
use gaunt_tp::tp::op::{apply_batch_par, BatchInputs};
use gaunt_tp::tp::{CgPlan, ConvMethod, GauntPlan};
use gaunt_tp::util::bench::{budget_ms, consume, smoke, BenchTable};
use gaunt_tp::util::pool;
use gaunt_tp::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(0);
    let mut t = BenchTable::new(
        "fig1a: feature interaction, full TP x->x (batch of 16 pairs)",
    );
    let batch = 16usize;
    let ls: &[usize] =
        if smoke() { &[2] } else { &[1, 2, 3, 4, 5, 6, 8] };
    let budget = budget_ms(150);
    for &l in ls {
        let n = num_coeffs(l);
        let x1 = rng.normals(batch * n);
        let x2 = rng.normals(batch * n);
        // CG baseline (sparse nonzero iteration, as e3nn compiles it)
        let cg = CgPlan::new(l, l, l);
        t.run(&format!("cg_sparse       L={l} (nnz={})", cg.nnz()), budget, || {
            consume(cg.apply_batch(&x1, &x2, batch));
        });
        if l <= 5 && !smoke() {
            t.run(&format!("cg_dense        L={l}"), budget, || {
                let mut out = Vec::new();
                for r in 0..batch {
                    out = cg.apply_dense(&x1[r * n..(r + 1) * n],
                                         &x2[r * n..(r + 1) * n]);
                }
                consume(out);
            });
        }
        // Gaunt TP
        let gd = GauntPlan::new(l, l, l, ConvMethod::Direct);
        t.run(&format!("gaunt_direct    L={l}"), budget, || {
            consume(gd.apply_batch(&x1, &x2, batch));
        });
        let gf = GauntPlan::new(l, l, l, ConvMethod::Fft);
        t.run(&format!("gaunt_fft       L={l}"), budget, || {
            consume(gf.apply_batch(&x1, &x2, batch));
        });
        // legacy (pre-plan) FFT pipeline: allocating conv2d_fft with
        // per-stage twiddle recomputation — the "before" row
        let panels = sh2f_panels(l);
        let n_side = 2 * l + 1;
        t.run(&format!("gaunt_fft_legacy L={l}"), budget, || {
            let mut out = Vec::new();
            for r in 0..batch {
                let u1 = GauntPlan::sh2f(&panels, &x1[r * n..(r + 1) * n]);
                let u2 = GauntPlan::sh2f(&panels, &x2[r * n..(r + 1) * n]);
                let u3 = conv2d_fft(&u1, n_side, &u2, n_side);
                out = gf.f2sh(&u3);
            }
            consume(out);
        });
    }
    // engine rows: cached plans + multi-threaded batched apply (the
    // serving configuration; single-thread rows above are the baseline)
    if !smoke() {
        let threads = pool::default_threads();
        let batch_par = 64usize;
        let cache = PlanCache::global();
        for l in [2usize, 4, 6, 8] {
            let n = num_coeffs(l);
            let x1 = rng.normals(batch_par * n);
            let x2 = rng.normals(batch_par * n);
            // cached plans dispatched through the ONE generic batched
            // driver (the serving configuration)
            let gf = cache.gaunt(l, l, l, ConvMethod::Fft);
            t.run(
                &format!("gaunt_fft_par   L={l} B={batch_par} x{threads}"),
                budget,
                || {
                    consume(apply_batch_par(
                        gf.as_ref(), &BatchInputs::pair(&x1, &x2),
                        batch_par, 0,
                    ));
                },
            );
            if l <= 6 {
                let cg = cache.cg(l, l, l);
                t.run(
                    &format!("cg_sparse_par   L={l} B={batch_par} x{threads}"),
                    budget,
                    || {
                        consume(apply_batch_par(
                            cg.as_ref(), &BatchInputs::pair(&x1, &x2),
                            batch_par, 0,
                        ));
                    },
                );
            }
        }
    }

    // compiled end-to-end kernels (same execution stack for both methods)
    if !smoke() {
        if let Ok(engine) = Engine::new("artifacts") {
            let mut rng = Rng::new(1);
            for l in [1usize, 2, 3, 4] {
                let n = num_coeffs(l);
                for op in ["gaunt_tp", "cg_tp"] {
                    let name = format!("{op}_L{l}_B64");
                    if let Ok(exe) = engine.load(&name) {
                        let x1 = Tensor::F32(rng.normals_f32(64 * n));
                        let x2 = Tensor::F32(rng.normals_f32(64 * n));
                        t.run(&format!("xla_{op:<10} L={l} B=64"), 200, || {
                            consume(exe.run(&[x1.clone(), x2.clone()]).unwrap());
                        });
                    }
                }
            }
        } else {
            println!("(artifacts/ missing — skipping compiled-kernel rows)");
        }
    }
    if smoke() {
        println!("[smoke] fig1a OK ({} rows)", t.rows.len());
    } else {
        t.write_tsv("fig1a");
    }
}
