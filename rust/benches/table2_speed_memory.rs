//! Table 2 (bottom rows) — training-step speed-up and memory cost of the
//! Gaunt parameterization vs the CG baseline, measured end-to-end on the
//! compiled train-step artifacts, plus the many-body memory comparison
//! (MACE-style precomputed tensors vs the Gaunt pipeline's tables).

use gaunt_tp::data::{gen_bpa_dataset, PaddedBatch};
use gaunt_tp::experiments::ff_batch_tensors;
use gaunt_tp::num_coeffs;
use gaunt_tp::runtime::Engine;
use gaunt_tp::tp::engine::{gaunt_apply_batch_par, PlanCache};
use gaunt_tp::tp::many_body::MaceStylePlan;
use gaunt_tp::tp::ConvMethod;
use gaunt_tp::fourier::tables::{f2sh_panels, sh2f_panels};
use gaunt_tp::util::bench::{consume, BenchTable};
use gaunt_tp::util::pool;
use gaunt_tp::util::rng::Rng;

fn main() {
    let mut t = BenchTable::new("table2: train-step speed (batch 8) + memory");
    match Engine::new("artifacts") {
        Ok(engine) => {
            let graphs = gen_bpa_dataset(&[0.05], 8, 3).remove(0);
            let pb = PaddedBatch::from_graphs(&graphs, 8, 32, 128, 4.0);
            for variant in ["gaunt", "cg"] {
                let exe = match engine.load(&format!("ff_train_step_{variant}")) {
                    Ok(e) => e,
                    Err(e) => {
                        println!("skipping {variant}: {e}");
                        continue;
                    }
                };
                let state: Vec<_> = engine
                    .load_state_blob(&format!("ff_state_init_{variant}"))
                    .unwrap()
                    .into_iter()
                    .map(|(_, x)| x)
                    .collect();
                let mut inputs = state.clone();
                inputs.extend(ff_batch_tensors(&pb, true));
                t.run(&format!("train_step_{variant}"), 2500, || {
                    consume(exe.run(&inputs).unwrap());
                });
            }
        }
        Err(e) => println!("(artifacts missing: {e})"),
    }

    // batched-TP speed: single-thread vs the engine's sharded worker pool
    // over cached plans (the serving configuration) — the native speed
    // rows of Table 2
    let threads = pool::default_threads();
    let rows = 128usize;
    let mut rng = Rng::new(0);
    let mut tp = BenchTable::new(&format!(
        "table2: batched Gaunt TP, rows={rows}, 1 vs {threads} threads"
    ));
    for l in [2usize, 4, 6] {
        let n = num_coeffs(l);
        let x1 = rng.normals(rows * n);
        let x2 = rng.normals(rows * n);
        let plan = PlanCache::global().gaunt(l, l, l, ConvMethod::Auto);
        tp.run(&format!("gaunt_batch     L={l} x1"), 300, || {
            consume(plan.apply_batch(&x1, &x2, rows));
        });
        tp.run(&format!("gaunt_batch_par L={l} x{threads}"), 300, || {
            consume(gaunt_apply_batch_par(&plan, &x1, &x2, rows, 0));
        });
    }
    println!("\n-- multi-thread speedup (rows/s ratio) --");
    for pair in tp.rows.chunks(2) {
        if pair.len() == 2 {
            println!(
                "{:<32} -> {:<32} speedup {:.2}x",
                pair[0].name,
                pair[1].name,
                pair[0].median_ns / pair[1].median_ns
            );
        }
    }
    tp.write_tsv("table2_tp_scaling");

    // memory: MACE-style composite coupling tensors vs Gaunt tables
    println!("\n-- memory footprint (nu=3 many-body) --");
    for l in [1usize, 2, 3] {
        let mace = MaceStylePlan::new(3, l, l);
        let p = sh2f_panels(l);
        let f = f2sh_panels(l, 3 * l);
        let gaunt_bytes: usize = p
            .panels
            .iter()
            .chain(f.panels.iter())
            .map(|v| v.len() * 16)
            .sum();
        println!(
            "L={l}: mace_precomputed = {:>10} B   gaunt_tables = {:>8} B   \
             ratio {:.1}x",
            mace.memory_bytes(),
            gaunt_bytes,
            mace.memory_bytes() as f64 / gaunt_bytes as f64
        );
    }
    t.write_tsv("table2_speed");
}
