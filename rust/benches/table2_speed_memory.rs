//! Table 2 (bottom rows) — training-step speed-up and memory cost of the
//! Gaunt parameterization vs the CG baseline, measured end-to-end on the
//! compiled train-step artifacts, plus the many-body memory comparison
//! (MACE-style precomputed tensors vs the Gaunt pipeline's tables).
//!
//! Also the Fourier-plan-layer acceptance measurement: per-L single-pair
//! Gaunt TP through (a) the planned Hermitian FFT path, (b) the legacy
//! allocating `conv2d_fft` path, and (c) the direct convolution — with
//! explicit `speedup_*` ratio rows in the TSV and the measured
//! Direct/FFT crossover (the constant behind `ConvMethod::Auto`,
//! `gaunt::AUTO_FFT_CROSSOVER`).
//!
//! `--smoke`: one tiny size, 1 ms budgets, no TSV (CI liveness check).

use gaunt_tp::data::{gen_bpa_dataset, PaddedBatch};
use gaunt_tp::experiments::ff_batch_tensors;
use gaunt_tp::fourier::conv::conv2d_fft;
use gaunt_tp::num_coeffs;
use gaunt_tp::runtime::Engine;
use gaunt_tp::tp::engine::PlanCache;
use gaunt_tp::tp::op::{apply_batch_par, BatchInputs};
use gaunt_tp::tp::many_body::MaceStylePlan;
use gaunt_tp::tp::{ConvMethod, Gaunt32Plan, GauntPlan};
use gaunt_tp::fourier::tables::{f2sh_panels, sh2f_panels};
use gaunt_tp::util::bench::{budget_ms, consume, smoke, BenchTable,
                            Measurement};
use gaunt_tp::util::pool;
use gaunt_tp::util::rng::Rng;

fn main() {
    let budget = budget_ms(300);
    let mut t = BenchTable::new("table2: train-step speed (batch 8) + memory");
    if !smoke() {
        match Engine::new("artifacts") {
            Ok(engine) => {
                let graphs = gen_bpa_dataset(&[0.05], 8, 3).remove(0);
                let pb = PaddedBatch::from_graphs(&graphs, 8, 32, 128, 4.0);
                for variant in ["gaunt", "cg"] {
                    let exe = match engine
                        .load(&format!("ff_train_step_{variant}"))
                    {
                        Ok(e) => e,
                        Err(e) => {
                            println!("skipping {variant}: {e}");
                            continue;
                        }
                    };
                    let state: Vec<_> = engine
                        .load_state_blob(&format!("ff_state_init_{variant}"))
                        .unwrap()
                        .into_iter()
                        .map(|(_, x)| x)
                        .collect();
                    let mut inputs = state.clone();
                    inputs.extend(ff_batch_tensors(&pb, true));
                    t.run(&format!("train_step_{variant}"), 2500, || {
                        consume(exe.run(&inputs).unwrap());
                    });
                }
            }
            Err(e) => println!("(artifacts missing: {e})"),
        }
    }

    // ------------------------------------------------------------------
    // Fourier plan layer: planned FFT vs legacy conv2d_fft vs direct,
    // single pair per iteration, per degree L (l1 = l2 = l3 = L).
    // ------------------------------------------------------------------
    let mut rng = Rng::new(0);
    let mut fp = BenchTable::new(
        "table2: Gaunt conv backends per L (planned vs legacy vs direct)",
    );
    let ls: &[usize] = if smoke() { &[2] } else { &[2, 3, 4, 5, 6, 8] };
    let mut trio: Vec<(usize, f64, f64, f64, f64)> = Vec::new();
    for &l in ls {
        let n = num_coeffs(l);
        let x1 = rng.normals(n);
        let x2 = rng.normals(n);
        let planned = GauntPlan::new(l, l, l, ConvMethod::Fft);
        let mut scratch = planned.scratch();
        let mut out = vec![0.0; n];
        let m_planned = {
            let m = gaunt_tp::util::bench::bench(
                &format!("gaunt_fft_planned L={l}"),
                budget,
                || {
                    planned.apply_into(&x1, &x2, &mut out, &mut scratch);
                    consume(&out);
                },
            );
            fp.add(m.clone());
            m
        };
        let panels = sh2f_panels(l);
        let n_side = 2 * l + 1;
        let m_legacy = {
            let m = gaunt_tp::util::bench::bench(
                &format!("gaunt_fft_legacy  L={l}"),
                budget,
                || {
                    let u1 = GauntPlan::sh2f(&panels, &x1);
                    let u2 = GauntPlan::sh2f(&panels, &x2);
                    let u3 = conv2d_fft(&u1, n_side, &u2, n_side);
                    consume(planned.f2sh(&u3));
                },
            );
            fp.add(m.clone());
            m
        };
        let direct = GauntPlan::new(l, l, l, ConvMethod::Direct);
        let mut dscratch = direct.scratch();
        let m_direct = {
            let m = gaunt_tp::util::bench::bench(
                &format!("gaunt_direct      L={l}"),
                budget,
                || {
                    direct.apply_into(&x1, &x2, &mut out, &mut dscratch);
                    consume(&out);
                },
            );
            fp.add(m.clone());
            m
        };
        // the serving-precision row: same FFT pipeline, f32 interior
        let p32 = Gaunt32Plan::new(l, l, l, ConvMethod::Fft);
        let mut s32 = p32.scratch();
        let m_f32 = {
            let m = gaunt_tp::util::bench::bench(
                &format!("gaunt_fft_f32     L={l}"),
                budget,
                || {
                    p32.apply_into(&x1, &x2, &mut out, &mut s32);
                    consume(&out);
                },
            );
            fp.add(m.clone());
            m
        };
        trio.push((
            l,
            m_planned.median_ns,
            m_legacy.median_ns,
            m_direct.median_ns,
            m_f32.median_ns,
        ));
    }
    // ratio rows (median_ns carries the ratio; mad 0, iters 0 marks them
    // as derived) + measured crossover
    println!("\n-- planned-FFT speedups (ratio > 1 means planned wins) --");
    let mut crossover: Option<usize> = None;
    for &(l, p, leg, d, f32ns) in &trio {
        let vs_legacy = leg / p;
        let vs_direct = d / p;
        let vs_f32 = p / f32ns;
        println!(
            "L={l}: legacy/planned = {vs_legacy:.2}x   \
             direct/planned = {vs_direct:.2}x   \
             f64/f32 = {vs_f32:.2}x"
        );
        fp.add(Measurement {
            name: format!("speedup_legacy_over_planned L={l}"),
            median_ns: vs_legacy,
            mad_ns: 0.0,
            iters: 0,
        });
        fp.add(Measurement {
            name: format!("speedup_direct_over_planned L={l}"),
            median_ns: vs_direct,
            mad_ns: 0.0,
            iters: 0,
        });
        fp.add(Measurement {
            name: format!("speedup_f64_over_f32 L={l}"),
            median_ns: vs_f32,
            mad_ns: 0.0,
            iters: 0,
        });
        if crossover.is_none() && vs_direct >= 1.0 {
            crossover = Some(l);
        }
    }
    match crossover {
        Some(l) => println!(
            "measured Direct->FFT crossover: L = {l} (l1 + l2 = {}); \
             ConvMethod::Auto ships AUTO_FFT_CROSSOVER = {}",
            2 * l,
            gaunt_tp::tp::gaunt::AUTO_FFT_CROSSOVER
        ),
        None => println!(
            "direct conv won at every measured L (crossover above L = {}); \
             ConvMethod::Auto ships AUTO_FFT_CROSSOVER = {}",
            ls.last().unwrap(),
            gaunt_tp::tp::gaunt::AUTO_FFT_CROSSOVER
        ),
    }
    if !smoke() {
        fp.write_tsv("table2_fourier_plan");
    }

    // batched-TP speed: single-thread vs the engine's sharded worker pool
    // over cached plans (the serving configuration) — the native speed
    // rows of Table 2
    let threads = pool::default_threads();
    let rows = 128usize;
    let mut tp = BenchTable::new(&format!(
        "table2: batched Gaunt TP, rows={rows}, 1 vs {threads} threads"
    ));
    let ls_tp: &[usize] = if smoke() { &[2] } else { &[2, 4, 6] };
    for &l in ls_tp {
        let n = num_coeffs(l);
        let x1 = rng.normals(rows * n);
        let x2 = rng.normals(rows * n);
        let plan = PlanCache::global().gaunt(l, l, l, ConvMethod::Auto);
        tp.run(&format!("gaunt_batch     L={l} x1"), budget, || {
            consume(plan.apply_batch(&x1, &x2, rows));
        });
        tp.run(&format!("gaunt_batch_par L={l} x{threads}"), budget, || {
            consume(apply_batch_par(
                plan.as_ref(), &BatchInputs::pair(&x1, &x2), rows, 0,
            ));
        });
    }
    println!("\n-- multi-thread speedup (rows/s ratio) --");
    for pair in tp.rows.chunks(2) {
        if pair.len() == 2 {
            println!(
                "{:<32} -> {:<32} speedup {:.2}x",
                pair[0].name,
                pair[1].name,
                pair[0].median_ns / pair[1].median_ns
            );
        }
    }
    if !smoke() {
        tp.write_tsv("table2_tp_scaling");
    }

    // memory: MACE-style composite coupling tensors vs Gaunt tables
    if !smoke() {
        println!("\n-- memory footprint (nu=3 many-body) --");
        for l in [1usize, 2, 3] {
            let mace = MaceStylePlan::new(3, l, l);
            let p = sh2f_panels(l);
            let f = f2sh_panels(l, 3 * l);
            let gaunt_bytes: usize = p
                .panels
                .iter()
                .chain(f.panels.iter())
                .map(|v| v.len() * 16)
                .sum();
            println!(
                "L={l}: mace_precomputed = {:>10} B   gaunt_tables = {:>8} B   \
                 ratio {:.1}x",
                mace.memory_bytes(),
                gaunt_bytes,
                mace.memory_bytes() as f64 / gaunt_bytes as f64
            );
        }
        t.write_tsv("table2_speed");
    } else {
        println!(
            "[smoke] table2 OK ({} rows)",
            t.rows.len() + fp.rows.len() + tp.rows.len()
        );
    }
}
