//! Table 2 (bottom rows) — training-step speed-up and memory cost of the
//! Gaunt parameterization vs the CG baseline, measured end-to-end on the
//! compiled train-step artifacts, plus the many-body memory comparison
//! (MACE-style precomputed tensors vs the Gaunt pipeline's tables).

use gaunt_tp::data::{gen_bpa_dataset, PaddedBatch};
use gaunt_tp::experiments::ff_batch_tensors;
use gaunt_tp::runtime::Engine;
use gaunt_tp::tp::many_body::MaceStylePlan;
use gaunt_tp::fourier::tables::{f2sh_panels, sh2f_panels};
use gaunt_tp::util::bench::{consume, BenchTable};

fn main() {
    let mut t = BenchTable::new("table2: train-step speed (batch 8) + memory");
    match Engine::new("artifacts") {
        Ok(engine) => {
            let graphs = gen_bpa_dataset(&[0.05], 8, 3).remove(0);
            let pb = PaddedBatch::from_graphs(&graphs, 8, 32, 128, 4.0);
            for variant in ["gaunt", "cg"] {
                let exe = match engine.load(&format!("ff_train_step_{variant}")) {
                    Ok(e) => e,
                    Err(e) => {
                        println!("skipping {variant}: {e}");
                        continue;
                    }
                };
                let state: Vec<_> = engine
                    .load_state_blob(&format!("ff_state_init_{variant}"))
                    .unwrap()
                    .into_iter()
                    .map(|(_, x)| x)
                    .collect();
                let mut inputs = state.clone();
                inputs.extend(ff_batch_tensors(&pb, true));
                t.run(&format!("train_step_{variant}"), 2500, || {
                    consume(exe.run(&inputs).unwrap());
                });
            }
        }
        Err(e) => println!("(artifacts missing: {e})"),
    }

    // memory: MACE-style composite coupling tensors vs Gaunt tables
    println!("\n-- memory footprint (nu=3 many-body) --");
    for l in [1usize, 2, 3] {
        let mace = MaceStylePlan::new(3, l, l);
        let p = sh2f_panels(l);
        let f = f2sh_panels(l, 3 * l);
        let gaunt_bytes: usize = p
            .panels
            .iter()
            .chain(f.panels.iter())
            .map(|v| v.len() * 16)
            .sum();
        println!(
            "L={l}: mace_precomputed = {:>10} B   gaunt_tables = {:>8} B   \
             ratio {:.1}x",
            mace.memory_bytes(),
            gaunt_bytes,
            mace.memory_bytes() as f64 / gaunt_bytes as f64
        );
    }
    t.write_tsv("table2_speed");
}
