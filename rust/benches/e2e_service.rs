//! End-to-end serving benchmark: throughput/latency of the coordinator
//! under closed-loop load (the system-level claim: L3 overhead is small
//! next to executable runtime).

use std::sync::Arc;
use std::time::{Duration, Instant};

use gaunt_tp::coordinator::batcher::BatchPolicy;
use gaunt_tp::coordinator::{ForceFieldServer, ServerConfig};
use gaunt_tp::data::gen_bpa_dataset;
use gaunt_tp::runtime::Engine;

fn main() {
    let engine = match Engine::new("artifacts") {
        Ok(e) => Arc::new(e),
        Err(e) => {
            println!("artifacts missing: {e}");
            return;
        }
    };
    println!("== e2e service benchmark ==");
    let structures = gen_bpa_dataset(&[0.05], 16, 5).remove(0);
    for (max_batch, n_workers) in [(1usize, 1usize), (4, 1), (8, 1), (8, 2)] {
        let server = ForceFieldServer::start(
            engine.clone(),
            ServerConfig {
                policy: BatchPolicy {
                    max_batch,
                    max_wait: Duration::from_millis(2),
                    max_queue: 8192,
                },
                n_workers,
                ..Default::default()
            },
        )
        .unwrap();
        let n_requests = 96usize;
        let t0 = Instant::now();
        let tickets: Vec<_> = (0..n_requests)
            .map(|i| {
                let g = &structures[i % structures.len()];
                server.submit(g.pos.clone(), g.species.clone()).unwrap()
            })
            .collect();
        for t in tickets {
            t.wait().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "batch<= {max_batch} workers={n_workers}: {:.1} req/s | {}",
            n_requests as f64 / wall,
            server.metrics().report()
        );
        server.shutdown();
    }
}
