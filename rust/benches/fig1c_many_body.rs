//! Fig. 1 panels 3-4 — Equivariant Many-body Interaction efficiency.
//!
//! (a) fix nu = 3, sweep L;  (b) fix L = 2, sweep nu — against the
//! e3nn-style pairwise CG fold and the MACE-style precomputed composite
//! tensor (which trades memory for speed; its footprint is reported).

use gaunt_tp::num_coeffs;
use gaunt_tp::tp::many_body::{
    many_body_cg_fold, many_body_gaunt, MaceStylePlan,
};
use gaunt_tp::util::bench::{consume, BenchTable};
use gaunt_tp::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(0);

    let mut t = BenchTable::new("fig1c-a: many-body, nu=3, sweep L");
    for l in [1usize, 2, 3] {
        let xs: Vec<Vec<f64>> =
            (0..3).map(|_| rng.normals(num_coeffs(l))).collect();
        t.run(&format!("e3nn_cg_fold    L={l}"), 120, || {
            consume(many_body_cg_fold(&xs, l, l, 3 * l));
        });
        let mace = MaceStylePlan::new(3, l, l);
        t.run(
            &format!("mace_precomp    L={l} (mem {} KiB)",
                     mace.memory_bytes() / 1024),
            120,
            || {
                consume(mace.apply_self(&xs[0]));
            },
        );
        t.run(&format!("gaunt_seq       L={l}"), 120, || {
            consume(many_body_gaunt(&xs, l, l, false));
        });
        t.run(&format!("gaunt_dc        L={l}"), 120, || {
            consume(many_body_gaunt(&xs, l, l, true));
        });
    }
    t.write_tsv("fig1c_sweep_l");

    let mut t2 = BenchTable::new("fig1c-b: many-body, L=2, sweep nu");
    let l = 2usize;
    for nu in [2usize, 3, 4] {
        let xs: Vec<Vec<f64>> =
            (0..nu).map(|_| rng.normals(num_coeffs(l))).collect();
        t2.run(&format!("e3nn_cg_fold    nu={nu}"), 120, || {
            consume(many_body_cg_fold(&xs, l, l, nu * l));
        });
        if nu <= 3 {
            let mace = MaceStylePlan::new(nu, l, l);
            t2.run(
                &format!("mace_precomp    nu={nu} (mem {} KiB)",
                         mace.memory_bytes() / 1024),
                120,
                || {
                    consume(mace.apply_self(&xs[0]));
                },
            );
        }
        t2.run(&format!("gaunt_seq       nu={nu}"), 120, || {
            consume(many_body_gaunt(&xs, l, l, false));
        });
        t2.run(&format!("gaunt_dc        nu={nu}"), 120, || {
            consume(many_body_gaunt(&xs, l, l, true));
        });
    }
    t2.write_tsv("fig1c_sweep_nu");
}
