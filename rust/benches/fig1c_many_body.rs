//! Fig. 1 panels 3-4 — Equivariant Many-body Interaction efficiency.
//!
//! (a) fix nu = 3, sweep L;  (b) fix L = 2, sweep nu — against the
//! e3nn-style pairwise CG fold and the MACE-style precomputed composite
//! tensor (which trades memory for speed; its footprint is reported).
//!
//! `gaunt_plan` / `gaunt_plan_self` are the planned final-size-transform
//! rows (pointwise sample products instead of chained grid convolutions;
//! the self-product does a single transform + pointwise nu-th power).
//!
//! `--smoke`: one tiny size, 1 ms budgets, no TSV (CI liveness check).

use gaunt_tp::num_coeffs;
use gaunt_tp::tp::many_body::{
    many_body_cg_fold, many_body_gaunt, MaceStylePlan, ManyBodyPlan,
};
use gaunt_tp::util::bench::{budget_ms, consume, smoke, BenchTable};
use gaunt_tp::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(0);
    let budget = budget_ms(120);

    let mut t = BenchTable::new("fig1c-a: many-body, nu=3, sweep L");
    let ls: &[usize] = if smoke() { &[1] } else { &[1, 2, 3] };
    for &l in ls {
        let xs: Vec<Vec<f64>> =
            (0..3).map(|_| rng.normals(num_coeffs(l))).collect();
        t.run(&format!("e3nn_cg_fold    L={l}"), budget, || {
            consume(many_body_cg_fold(&xs, l, l, 3 * l));
        });
        let mace = MaceStylePlan::new(3, l, l);
        t.run(
            &format!("mace_precomp    L={l} (mem {} KiB)",
                     mace.memory_bytes() / 1024),
            budget,
            || {
                consume(mace.apply_self(&xs[0]));
            },
        );
        t.run(&format!("gaunt_seq       L={l}"), budget, || {
            consume(many_body_gaunt(&xs, l, l, false));
        });
        t.run(&format!("gaunt_dc        L={l}"), budget, || {
            consume(many_body_gaunt(&xs, l, l, true));
        });
        let plan = ManyBodyPlan::new(3, l, l);
        let mut scratch = plan.scratch();
        let mut out = vec![0.0; num_coeffs(l)];
        t.run(&format!("gaunt_plan      L={l}"), budget, || {
            plan.apply_into(&xs, &mut out, &mut scratch);
            consume(&out);
        });
        t.run(&format!("gaunt_plan_self L={l}"), budget, || {
            plan.apply_self_into(&xs[0], &mut out, &mut scratch);
            consume(&out);
        });
    }
    if !smoke() {
        t.write_tsv("fig1c_sweep_l");
    }

    if smoke() {
        println!("[smoke] fig1c OK ({} rows)", t.rows.len());
        return;
    }

    let mut t2 = BenchTable::new("fig1c-b: many-body, L=2, sweep nu");
    let l = 2usize;
    for nu in [2usize, 3, 4] {
        let xs: Vec<Vec<f64>> =
            (0..nu).map(|_| rng.normals(num_coeffs(l))).collect();
        t2.run(&format!("e3nn_cg_fold    nu={nu}"), budget, || {
            consume(many_body_cg_fold(&xs, l, l, nu * l));
        });
        if nu <= 3 {
            let mace = MaceStylePlan::new(nu, l, l);
            t2.run(
                &format!("mace_precomp    nu={nu} (mem {} KiB)",
                         mace.memory_bytes() / 1024),
                budget,
                || {
                    consume(mace.apply_self(&xs[0]));
                },
            );
        }
        t2.run(&format!("gaunt_seq       nu={nu}"), budget, || {
            consume(many_body_gaunt(&xs, l, l, false));
        });
        t2.run(&format!("gaunt_dc        nu={nu}"), budget, || {
            consume(many_body_gaunt(&xs, l, l, true));
        });
        let plan = ManyBodyPlan::new(nu, l, l);
        let mut scratch = plan.scratch();
        let mut out = vec![0.0; num_coeffs(l)];
        t2.run(&format!("gaunt_plan      nu={nu}"), budget, || {
            plan.apply_into(&xs, &mut out, &mut scratch);
            consume(&out);
        });
        t2.run(&format!("gaunt_plan_self nu={nu}"), budget, || {
            plan.apply_self_into(&xs[0], &mut out, &mut scratch);
            consume(&out);
        });
    }
    t2.write_tsv("fig1c_sweep_nu");
}
