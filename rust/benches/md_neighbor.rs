//! Neighbor-stack benchmark: open vs periodic cell-list builds, Verlet
//! rebuild vs reuse, and a large periodic LJ rollout, at 10^3 / 10^4 /
//! 10^5 atoms (simple-cubic LJ boxes at reduced density 0.8).
//!
//! Feeds the `md_neighbor` rows of BENCH_fourier.json via
//! `scripts/bench_snapshot.sh`.  The headline claims measured here:
//! the periodic build stays O(N) (ns/atom flat across three decades),
//! a Verlet reuse step costs a displacement scan instead of a rebuild,
//! and a 10^5-atom periodic rollout is a routine workload.
//!
//! `--smoke`: tiny sizes and budgets, a 3-step 10^5-atom rollout (the
//! acceptance check that million-class periodic MD completes), no TSV.

use std::time::Instant;

use gaunt_tp::md::{
    neighbors_cell, neighbors_periodic_cell, neighbors_periodic_par,
    Integrator, Molecule, PeriodicPotential, Thermostat, VerletList,
};
use gaunt_tp::util::bench::{budget_ms, consume, smoke, BenchTable, Measurement};
use gaunt_tp::util::rng::Rng;

const RHO: f64 = 0.8;
const R_CUT: f64 = 2.5;
const SKIN: f64 = 0.4;

fn main() {
    let mut t = BenchTable::new("md_neighbor: cell lists / Verlet / rollout");
    // n_side 10 / 22 / 47 -> 1_000 / 10_648 / 103_823 atoms.  Smoke
    // uses n_side 6 (216 atoms): the smallest box whose minimum-image
    // bound 0.5*L ~ 3.23 still admits R_CUT + SKIN = 2.9 for the
    // Verlet builder.
    let sides: &[usize] = if smoke() { &[6] } else { &[10, 22, 47] };
    let budget = budget_ms(150);

    for &n_side in sides {
        let (mol, cell) = Molecule::lj_box(n_side, RHO, R_CUT);
        let n = mol.pos.len();
        let pos = &mol.pos;

        t.run(&format!("open_cell_list  n={n}"), budget, || {
            consume(neighbors_cell(pos, R_CUT));
        });
        t.run(&format!("periodic_cell_list  n={n}"), budget, || {
            consume(neighbors_periodic_cell(pos, &cell, R_CUT));
        });
        t.run(&format!("periodic_par_all_cores  n={n}"), budget, || {
            consume(neighbors_periodic_par(pos, &cell, R_CUT, 0));
        });

        // Verlet: a rebuild step (positions jump past skin/2 every
        // call) vs a reuse step (displacement scan only)
        {
            let mut vl = VerletList::periodic(cell.clone(), R_CUT, SKIN);
            let a = pos.clone();
            let mut b = pos.clone();
            for p in b.iter_mut() {
                p[0] += 0.6 * SKIN; // past skin/2: every alternation rebuilds
            }
            let mut flip = false;
            t.run(&format!("verlet_rebuild  n={n}"), budget, || {
                flip = !flip;
                consume(vl.update(if flip { &b } else { &a }));
            });
            let rebuilds = vl.rebuilds;
            assert!(rebuilds > 2, "rebuild bench never rebuilt");
            vl.update(&a);
            t.run(&format!("verlet_reuse  n={n}"), budget, || {
                consume(vl.update(&a));
            });
            assert!(
                vl.rebuilds <= rebuilds + 1,
                "reuse bench kept rebuilding"
            );
        }
    }

    // --- large periodic LJ rollout: velocity-Verlet MD through the
    // skin-buffered Verlet list at 10^5 atoms.  One manually timed
    // row (a multi-second workload has no business inside the adaptive
    // micro-bench calibrator). ---
    {
        let n_side = 47; // 103_823 atoms, in smoke mode too: this IS
                         // the acceptance check that a 10^5-atom
                         // periodic rollout completes
        let steps = if smoke() { 3 } else { 25 };
        let (mol, cell) = Molecule::lj_box(n_side, RHO, R_CUT);
        let n = mol.pos.len();
        let mut pp =
            PeriodicPotential::new(mol.potential, mol.species.clone(), cell,
                                   SKIN);
        let mut rng = Rng::new(12);
        let mut md = Integrator::new_with(
            mol.pos, mol.species, &mut pp, 0.002, Thermostat::None,
        );
        md.thermalize(0.5, &mut rng);
        let t0 = Instant::now();
        for _ in 0..steps {
            md.step_with(&mut pp, &mut rng);
        }
        let ns = t0.elapsed().as_nanos() as f64 / steps as f64;
        assert!(
            md.pos.iter().all(|p| p.iter().all(|v| v.is_finite())),
            "periodic rollout diverged"
        );
        t.add(Measurement {
            name: format!("periodic_lj_rollout_step  n={n}"),
            median_ns: ns,
            mad_ns: 0.0,
            iters: steps,
        });
        println!(
            "    -> {:.0} atom-steps/sec, {} rebuilds / {} reuses over \
             {steps} steps",
            n as f64 / (ns * 1e-9),
            pp.list().rebuilds,
            pp.list().reuses,
        );
    }

    if smoke() {
        println!("[smoke] md_neighbor OK ({} rows)", t.rows.len());
    } else {
        t.write_tsv("md_neighbor");
    }
}
